//! `equilibrium` — the command-line entry point.
//!
//! Subcommands:
//!
//! * `generate`  — emit a synthetic cluster state dump (paper clusters A–F
//!   or the demo cluster)
//! * `balance`   — plan movements for a dumped cluster state
//! * `simulate`  — run both balancers from the same state and compare
//! * `report`    — regenerate the paper's tables/figures (table1, fig4,
//!   fig5, fig6, ablate-k, ablate-count)
//! * `daemon`    — run the operational loop (writes → plan → throttled
//!   execution)
//! * `scenario`  — list or run discrete-event scenario timelines (the
//!   paper's §3 situations plus compound churn scenarios)
//! * `fleet`     — deterministic multi-seed scenario sweeps: run a sweep,
//!   compare raw vs piped execution, or gate a sweep against a committed
//!   statistical baseline (RFC 0004)
//! * `fuzz`      — chaos scenario fuzzing: sweep generated timelines
//!   through the invariant machine, minimize failures, and promote them
//!   into the regression corpus (RFC 0005)
//! * `estate`    — multi-cluster estate coordinator: run named estate
//!   cases under a pluggable router, sweep them across seeds, and
//!   render the cross-cluster comparison (RFC 0008)
//! * `runtime-info` — show PJRT artifact status

use std::path::PathBuf;
use std::process::ExitCode;

use equilibrium::app_err;
use equilibrium::balancer::{Balancer, EquilibriumConfig, MgrBalancer};
use equilibrium::cluster::{dump, snapshot};
use equilibrium::coordinator::{run_daemon, DaemonConfig, ExecutorConfig};
use equilibrium::crush::Level;
use equilibrium::fleet::{self, FleetConfig, GateConfig};
use equilibrium::generator::clusters;
use equilibrium::plan::{schedule_plan, PlanConfig, ScheduleConfig};
use equilibrium::report::{self, Scoring};
use equilibrium::runtime::Runtime;
use equilibrium::simulator::{simulate, SimOptions};
use equilibrium::util::cli::Cli;
use equilibrium::util::error::AppResult;
use equilibrium::util::units::{fmt_bytes_f, fmt_duration, to_tib_f, GIB};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "generate" => cmd_generate(rest),
        "balance" => cmd_balance(rest),
        "simulate" => cmd_simulate(rest),
        "report" => cmd_report(rest),
        "daemon" => cmd_daemon(rest),
        "scenario" => cmd_scenario(rest),
        "fleet" => cmd_fleet(rest),
        "fuzz" => cmd_fuzz(rest),
        "estate" => cmd_estate(rest),
        "df" => cmd_df(rest),
        "crush" => cmd_crush(rest),
        "runtime-info" => cmd_runtime_info(),
        "--help" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(app_err!("unknown subcommand '{other}'\n\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "equilibrium — size-aware shard balancing for Ceph-like clusters\n\n\
     Subcommands:\n\
     \x20 generate      --cluster <a..f|demo> [--seed N] [--out FILE[.eqsnap]]\n\
     \x20 balance       --state FILE[.eqsnap] [--balancer equilibrium|mgr|asura|bounded]\n\
     \x20                [--scoring native|xla]\n\
     \x20                [--max-moves N] [--k N] [--out FILE] [--optimize] [--phases]\n\
     \x20                [--max-backfills N] [--domain-level L] [--domain-backfills N]\n\
     \x20 simulate      --cluster <a..f|demo> [--seed N] [--scoring S] [--max-moves N]\n\
     \x20 report        <table1|fig4|fig5|fig6|plan|fleet|ablate-k|ablate-count> [--clusters a,b,..]\n\
     \x20                [--scoring S] [--seed N] [--out-dir DIR] [--baseline FILE]\n\
     \x20 daemon        --cluster <a..f|demo> [--rounds N] [--write-gib X] [--moves-per-round N]\n\
     \x20                [--optimize] [--phases]\n\
     \x20 scenario      list | run [--name NAME | --all | --spec FILE] [--seed N] [--reduced]\n\
     \x20                [--out-dir DIR] [--snapshot-dir DIR] [--quiet] [--optimize] [--phases]\n\
     \x20 fleet         run [--name NAME] [--seeds N] [--seed-base N] [--reduced|--smoke]\n\
     \x20                [--optimize] [--phases] [--out FILE] [--out-dir DIR] [--quiet]\n\
     \x20                [--checkpoint DIR | --resume DIR] [--max-cells N]\n\
     \x20                | compare [same sweep flags] [--balancers A,B,..] [--out FILE]\n\
     \x20                [--out-dir DIR] [--quiet]   (balancer bake-off with --balancers)\n\
     \x20                | gate --baseline FILE [--rel X]\n\
     \x20 fuzz          run [--cases N] [--seed-base N] [--profile P] [--reduced] [--chunk N]\n\
     \x20                [--out FILE] [--promote-dir DIR] [--quiet]\n\
     \x20                | gen --seed N [--profile P] [--reduced] [--out FILE]\n\
     \x20 estate        list | run [--name NAME | --all] [--router health|round-robin]\n\
     \x20                [--seeds N] [--seed-base N] [--reduced|--smoke] [--out FILE]\n\
     \x20                [--out-dir DIR] [--quiet]\n\
     \x20                | report --baseline FILE[,FILE..] [--out-dir DIR]\n\
     \x20 df            --cluster <a..f|demo> | --state FILE   (ceph-df-style report)\n\
     \x20 crush         --cluster <a..f|demo> | --state FILE [--tree]  (decompile CRUSH map)\n\
     \x20 runtime-info\n"
        .to_string()
}

fn scoring_from(args: &equilibrium::util::cli::Args) -> AppResult<Scoring> {
    match args.get_or("scoring", "native") {
        "native" => Ok(Scoring::Native),
        "xla" => Ok(Scoring::Xla),
        other => Err(app_err!("unknown scoring backend '{other}' (native|xla)")),
    }
}

fn level_from(name: &str) -> AppResult<Level> {
    match name {
        "osd" => Ok(Level::Osd),
        "host" => Ok(Level::Host),
        "rack" => Ok(Level::Rack),
        "row" => Ok(Level::Row),
        "datacenter" => Ok(Level::Datacenter),
        "root" => Ok(Level::Root),
        other => Err(app_err!("unknown failure-domain level '{other}' (osd|host|rack|row|datacenter|root)")),
    }
}

/// Build the plan pipeline config from the shared `--optimize` /
/// `--phases` (+ scheduler tuning) flags.
fn plan_config_from(a: &equilibrium::util::cli::Args) -> AppResult<PlanConfig> {
    let schedule = if a.flag("phases") {
        let osd_cap = a.get_u64("max-backfills")?.unwrap_or(1) as usize;
        Some(ScheduleConfig {
            max_backfills_per_osd: osd_cap,
            domain_level: level_from(a.get_or("domain-level", "host"))?,
            max_backfills_per_domain: a.get_u64("domain-backfills")?.unwrap_or(2) as usize,
            // the makespan-estimate model must simulate the same per-OSD
            // concurrency the phases were packed for
            executor: ExecutorConfig { max_backfills: osd_cap, ..ExecutorConfig::default() },
            ..ScheduleConfig::default()
        })
    } else {
        None
    };
    Ok(PlanConfig { optimize: a.flag("optimize") || schedule.is_some(), schedule })
}

fn load_cluster(name: &str, seed: u64) -> AppResult<equilibrium::cluster::ClusterState> {
    if name == "demo" {
        return Ok(clusters::demo(seed));
    }
    clusters::by_name(name, seed)
        .map(|c| c.state)
        .ok_or_else(|| app_err!("unknown cluster '{name}' (a..f or demo)"))
}

fn cmd_generate(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium generate", "emit a synthetic cluster dump")
        .opt_default("cluster", "NAME", "demo", "cluster to generate (a..f|demo)")
        .opt_default("seed", "N", "0", "generator seed")
        .opt("out", "FILE", "output path (default: stdout)");
    let a = cli.parse(argv.iter())?;
    let seed = a.get_u64("seed")?.unwrap_or(0);
    let state = load_cluster(a.get_or("cluster", "demo"), seed)?;
    match a.get("out") {
        // extension-negotiated: `.eqsnap` writes the RFC 0007 binary
        // format, anything else the JSON dump
        Some(path) => {
            save_state_file(path, &state)?;
            eprintln!("wrote {path}");
        }
        None => println!("{}", dump::dump(&state)),
    }
    Ok(())
}

/// Write a state to `path` in the format its extension selects
/// (`.eqsnap` → binary snapshot, anything else → JSON dump).
fn save_state_file(path: &str, state: &equilibrium::cluster::ClusterState) -> AppResult {
    snapshot::save_state(std::path::Path::new(path), state)
        .map_err(|e| app_err!("cannot write '{path}': {e}"))
}

/// Load a state from `path` in the format its extension selects.
fn load_state_file(path: &str) -> AppResult<equilibrium::cluster::ClusterState> {
    snapshot::load_state(std::path::Path::new(path))
        .map_err(|e| app_err!("cannot load '{path}': {e}"))
}

fn cmd_balance(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium balance", "plan movements for a cluster state")
        .opt("state", "FILE", "cluster dump (from `generate`)")
        .opt_default("balancer", "NAME", "equilibrium", "equilibrium|mgr|asura|bounded")
        .opt_default("scoring", "BACKEND", "native", "native|xla (equilibrium only)")
        .opt_default("max-moves", "N", "10000", "movement cap")
        .opt_default("k", "N", "25", "equilibrium: sources to try")
        .opt("out", "FILE", "write the resulting state dump here")
        .opt("upmap-script", "FILE", "write `ceph osd pg-upmap-items` commands here")
        .flag("optimize", "coalesce the plan to its minimal equivalent (RFC 0003)")
        .flag("phases", "schedule into concurrency-capped phases (implies --optimize)")
        .opt_default("max-backfills", "N", "1", "phases: concurrent transfers per OSD")
        .opt_default("domain-level", "LEVEL", "host", "phases: failure-domain level")
        .opt_default("domain-backfills", "N", "2", "phases: concurrent transfers per domain")
        .flag("quiet", "suppress per-move output");
    let a = cli.parse(argv.iter())?;
    let path = a
        .get("state")
        .ok_or_else(|| app_err!("--state is required"))?;
    let mut state = load_state_file(path)?;
    let initial = state.clone();

    let mut balancer: Box<dyn Balancer> = match a.get_or("balancer", "equilibrium") {
        "equilibrium" => report::make_equilibrium(
            scoring_from(&a)?,
            EquilibriumConfig { k: a.get_u64("k")?.unwrap_or(25) as usize, ..Default::default() },
        ),
        "mgr" => Box::new(MgrBalancer::default()),
        "asura" => Box::new(equilibrium::balancer::AsuraBalancer::default()),
        "bounded" => Box::new(equilibrium::balancer::BoundedEquilibrium::default()),
        other => return Err(app_err!("unknown balancer '{other}'")),
    };

    let plan_cfg = plan_config_from(&a)?;
    let opts = SimOptions {
        max_moves: a.get_u64("max-moves")?.unwrap_or(10_000) as usize,
        sample_every: usize::MAX, // only endpoints needed
        plan: plan_cfg.clone(),
    };
    let before_avail = state.total_max_avail(false);
    let before_var = state.utilization_variance();
    let res = simulate(balancer.as_mut(), &mut state, &opts);
    // the plan to ship: minimal when the pipeline ran, raw otherwise
    let final_plan: &[equilibrium::cluster::Movement] =
        res.optimized.as_deref().unwrap_or(&res.movements);
    if !a.flag("quiet") {
        for m in final_plan {
            println!("{m}");
        }
    }
    eprintln!(
        "{} moves, {} moved, avail {} -> {}, variance {:.3e} -> {:.3e}, calc {}",
        res.movements.len(),
        fmt_bytes_f(res.total_moved_bytes() as f64),
        fmt_bytes_f(before_avail),
        fmt_bytes_f(state.total_max_avail(false)),
        before_var,
        state.utilization_variance(),
        fmt_duration(res.total_calc_seconds),
    );
    if plan_cfg.optimize {
        eprintln!(
            "optimized: {} -> {} moves, {} -> {} to move ({} saved)",
            res.plan.raw_moves,
            res.plan.moves,
            fmt_bytes_f(res.plan.raw_bytes as f64),
            fmt_bytes_f(res.plan.bytes as f64),
            fmt_bytes_f(res.plan.saved_bytes() as f64),
        );
    }
    let phased = plan_cfg
        .schedule
        .as_ref()
        .map(|sched| schedule_plan(&initial, final_plan, sched));
    if let (Some(phased), Some(sched)) = (&phased, &plan_cfg.schedule) {
        eprintln!(
            "scheduled: {} phases, estimated makespan {}",
            phased.phases.len(),
            fmt_duration(phased.makespan(&sched.executor, initial.osd_count())),
        );
    }
    if let Some(out) = a.get("out") {
        save_state_file(out, &state)?;
        eprintln!("wrote {out}");
    }
    if let Some(path) = a.get("upmap-script") {
        let script = match &phased {
            // one block per phase: apply, wait for HEALTH_OK, continue
            Some(phased) => phased
                .render_scripts(&initial)
                .map_err(|e| app_err!("plan not applicable: {e}"))?
                .join("\n\n"),
            None => equilibrium::balancer::upmap_script::render_plan(&initial, final_plan)
                .map_err(|e| app_err!("plan not applicable: {e}"))?
                .join("\n"),
        };
        std::fs::write(path, script + "\n")?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_df(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium df", "ceph-df-style capacity report")
        .opt("cluster", "NAME", "generate and report (a..f|demo)")
        .opt("state", "FILE", "report a dumped state")
        .opt_default("seed", "N", "0", "generator seed")
        .opt_default("osd-rows", "N", "20", "max OSD rows shown");
    let a = cli.parse(argv.iter())?;
    let state = match (a.get("cluster"), a.get("state")) {
        (Some(name), None) => load_cluster(name, a.get_u64("seed")?.unwrap_or(0))?,
        (None, Some(path)) => load_state_file(path)?,
        _ => return Err(app_err!("exactly one of --cluster or --state is required")),
    };
    let report = equilibrium::cluster::health::df(&state);
    print!(
        "{}",
        equilibrium::cluster::health::render(&report, a.get_u64("osd-rows")?.unwrap_or(20) as usize)
    );
    Ok(())
}

fn cmd_crush(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium crush", "decompile the CRUSH map")
        .opt("cluster", "NAME", "generate and decompile (a..f|demo)")
        .opt("state", "FILE", "decompile a dumped state's map")
        .opt_default("seed", "N", "0", "generator seed")
        .flag("tree", "print the hierarchy tree instead of crushtool syntax");
    let a = cli.parse(argv.iter())?;
    let state = match (a.get("cluster"), a.get("state")) {
        (Some(name), None) => load_cluster(name, a.get_u64("seed")?.unwrap_or(0))?,
        (None, Some(path)) => load_state_file(path)?,
        _ => return Err(app_err!("exactly one of --cluster or --state is required")),
    };
    if a.flag("tree") {
        print!("{}", equilibrium::crush::text::tree(&state.crush));
    } else {
        print!("{}", equilibrium::crush::text::decompile(&state.crush));
    }
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium simulate", "compare both balancers on a cluster")
        .opt_default("cluster", "NAME", "demo", "cluster (a..f|demo)")
        .opt_default("seed", "N", "0", "generator seed")
        .opt_default("scoring", "BACKEND", "native", "native|xla")
        .opt_default("max-moves", "N", "10000", "movement cap");
    let a = cli.parse(argv.iter())?;
    let seed = a.get_u64("seed")?.unwrap_or(0);
    let name = a.get_or("cluster", "demo");
    let initial = load_cluster(name, seed)?;
    let opts = SimOptions {
        max_moves: a.get_u64("max-moves")?.unwrap_or(10_000) as usize,
        sample_every: usize::MAX,
        ..SimOptions::default()
    };
    let scoring = scoring_from(&a)?;
    let (mgr, eq) = equilibrium::simulator::compare(
        &initial,
        || Box::new(MgrBalancer::default()),
        || report::make_equilibrium(scoring, EquilibriumConfig::default()),
        &opts,
    );
    println!("cluster {name}: initial variance {:.3e}", initial.utilization_variance());
    for res in [&mgr, &eq] {
        let last = res.series.last().unwrap();
        println!(
            "  {:<12} moves {:>6}  moved {:>12}  gained {:>10}  final variance {:.3e}  calc {}",
            res.balancer,
            res.movements.len(),
            fmt_bytes_f(res.total_moved_bytes() as f64),
            fmt_bytes_f(res.series.total_gained(None)),
            last.variance,
            fmt_duration(res.total_calc_seconds),
        );
    }
    Ok(())
}

fn cmd_report(argv: &[String]) -> AppResult {
    let Some((which, rest)) = argv.split_first() else {
        return Err(app_err!(
            "report requires an artifact: table1|fig4|fig5|fig6|plan|fleet|ablate-k|ablate-count"
        ));
    };
    let cli = Cli::new("equilibrium report", "regenerate paper tables/figures")
        .opt_default("clusters", "LIST", "a,b,c,d,e,f", "comma-separated clusters (table1)")
        .opt_default("cluster", "NAME", "a", "cluster (ablations)")
        .opt_default("scoring", "BACKEND", "native", "native|xla")
        .opt_default("seed", "N", "0", "generator seed")
        .opt_default("out-dir", "DIR", "target/figures", "CSV output directory")
        .opt_default("max-moves", "N", "10000", "movement cap")
        .opt_default("baseline", "FILE", "FLEET_baseline.json", "fleet sweep JSON (report fleet)");
    let a = cli.parse(rest.iter())?;
    let seed = a.get_u64("seed")?.unwrap_or(0);
    let scoring = scoring_from(&a)?;
    let out_dir = PathBuf::from(a.get_or("out-dir", "target/figures"));
    let opts = SimOptions {
        max_moves: a.get_u64("max-moves")?.unwrap_or(10_000) as usize,
        sample_every: usize::MAX,
        ..SimOptions::default()
    };

    match which.as_str() {
        "table1" => {
            let names: Vec<&str> = a.get_or("clusters", "a,b,c,d,e,f").split(',').collect();
            let (table, _) = report::table1(&names, seed, scoring, &opts);
            println!("Table 1 — generated movement amounts and gained pool space");
            println!("{}", table.render());
        }
        "fig4" => {
            let (mgr, eq) = report::figure4(&out_dir, seed, scoring)?;
            println!(
                "fig4 (cluster A): mgr {} moves, equilibrium {} moves; CSVs in {}",
                mgr.movements.len(),
                eq.movements.len(),
                out_dir.display()
            );
        }
        "fig5" => {
            let (mgr, eq) = report::figure5(&out_dir, seed, scoring)?;
            println!(
                "fig5 (cluster B): mgr {} moves, equilibrium {} moves; CSVs in {}",
                mgr.movements.len(),
                eq.movements.len(),
                out_dir.display()
            );
        }
        "fig6" => {
            report::figure6(&out_dir, seed, scoring)?;
            println!("fig6 CSVs written to {}", out_dir.display());
        }
        "plan" => {
            let names: Vec<&str> = a.get_or("clusters", "a,b,c,d,e,f").split(',').collect();
            let t = report::plan_table(&names, seed, scoring, &opts, &ScheduleConfig::default());
            println!("Plan pipeline — bytes moved and makespan, raw vs optimized+phased");
            println!("{}", t.render());
        }
        "fleet" => {
            let path = a.get_or("baseline", "FLEET_baseline.json");
            let b = fleet::parse_baseline(&std::fs::read_to_string(path)?)
                .map_err(|e| app_err!("cannot load fleet baseline '{path}': {e}"))?;
            println!(
                "Fleet summary — {} scenarios × {} seeds ({}, {} pipeline)",
                b.scenarios.len(),
                b.meta.seeds,
                if b.meta.reduced { "reduced" } else { "full-size" },
                b.meta.pipeline,
            );
            println!("{}", report::fleet_table(&b).render());
            report::write_fleet_csv(&out_dir, &b)?;
        }
        "ablate-k" => {
            let t = report::ablate_k(a.get_or("cluster", "a"), seed, &[1, 5, 25, 100], scoring);
            println!("k ablation on cluster {}:", a.get_or("cluster", "a"));
            println!("{}", t.render());
        }
        "ablate-count" => {
            let t = report::ablate_count_criterion(a.get_or("cluster", "a"), seed, scoring);
            println!("PG-count criterion ablation on cluster {}:", a.get_or("cluster", "a"));
            println!("{}", t.render());
        }
        other => return Err(app_err!("unknown report artifact '{other}'")),
    }
    Ok(())
}

fn cmd_daemon(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium daemon", "operational loop with throttled execution")
        .opt_default("cluster", "NAME", "demo", "cluster (a..f|demo)")
        .opt_default("seed", "N", "0", "generator seed")
        .opt_default("rounds", "N", "10", "write/plan/execute rounds")
        .opt_default("moves-per-round", "N", "50", "movement budget per round")
        .opt_default("write-gib", "X", "0", "client writes per round (GiB)")
        .opt_default("max-backfills", "N", "1", "concurrent transfers per OSD")
        .opt("target-round-seconds", "T", "adaptive movement budget targeting T s/round")
        .flag("optimize", "coalesce each round's plan before execution (RFC 0003)")
        .flag("phases", "execute each round in failure-domain-capped phases (implies --optimize)")
        .opt_default("domain-level", "LEVEL", "host", "phases: failure-domain level")
        .opt_default("domain-backfills", "N", "2", "phases: concurrent transfers per domain")
        .opt_default("scoring", "BACKEND", "native", "native|xla");
    let a = cli.parse(argv.iter())?;
    let seed = a.get_u64("seed")?.unwrap_or(0);
    let mut state = load_cluster(a.get_or("cluster", "demo"), seed)?;
    let mut balancer = report::make_equilibrium(scoring_from(&a)?, EquilibriumConfig::default());
    let cfg = DaemonConfig {
        rounds: a.get_u64("rounds")?.unwrap_or(10) as usize,
        moves_per_round: a.get_u64("moves-per-round")?.unwrap_or(50) as usize,
        write_bytes_per_round: a.get_u64("write-gib")?.unwrap_or(0) * GIB,
        workload: equilibrium::simulator::WorkloadModel::Uniform,
        target_round_seconds: a.get_f64("target-round-seconds")?,
        executor: ExecutorConfig {
            max_backfills: a.get_u64("max-backfills")?.unwrap_or(1) as usize,
            ..Default::default()
        },
        plan: plan_config_from(&a)?,
        seed: seed ^ 0xDAEE,
    };
    let report = run_daemon(&mut state, balancer.as_mut(), &cfg);
    print!("{}", report.log.render());
    println!("\nper-round summary:");
    for r in &report.rounds {
        println!(
            "  round {:>2}: wrote {:>10}, {} moves ({:>10}), exec {:>10}, variance {:.3e}, avail {:.1} TiB",
            r.round,
            fmt_bytes_f(r.written_user_bytes as f64),
            r.planned_moves,
            fmt_bytes_f(r.moved_bytes as f64),
            fmt_duration(r.makespan),
            r.variance_after,
            to_tib_f(r.total_avail_after),
        );
    }
    if cfg.plan.enabled() {
        println!(
            "plan pipeline: {} planned -> {} executed ({} saved), {} phases over {} rounds",
            fmt_bytes_f(report.plan.raw_bytes as f64),
            fmt_bytes_f(report.plan.bytes as f64),
            fmt_bytes_f(report.plan.saved_bytes() as f64),
            report.plan.phases,
            report.plan.rounds,
        );
    }
    println!("total virtual time: {}", fmt_duration(report.elapsed));
    Ok(())
}

fn cmd_scenario(argv: &[String]) -> AppResult {
    let Some((which, rest)) = argv.split_first() else {
        return Err(app_err!("scenario requires an action: list|run"));
    };
    match which.as_str() {
        "list" => {
            println!("library scenarios (seeded, deterministic):");
            for (name, description) in equilibrium::scenario::library::CATALOG {
                println!("  {name:<28} {description}");
            }
            Ok(())
        }
        "run" => cmd_scenario_run(rest),
        other => Err(app_err!("unknown scenario action '{other}' (list|run)")),
    }
}

fn cmd_scenario_run(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium scenario run", "execute scenario timelines")
        .opt("name", "NAME", "library scenario to run (see `scenario list`)")
        .flag("all", "run the whole library")
        .opt("spec", "FILE", "replay a scenario spec JSON file (e.g. a corpus regression)")
        .opt_default("seed", "N", "0", "scenario seed")
        .flag("reduced", "reduced-size mode (small cluster, small volumes; CI smoke)")
        .opt("out-dir", "DIR", "write the unified time series CSVs here")
        .opt("snapshot-dir", "DIR", "write `snapshot` events as binary .eqsnap files here")
        .flag("optimize", "run balance-round plans through the optimizer (RFC 0003)")
        .flag("phases", "execute plans in failure-domain-capped phases (implies --optimize)")
        .opt_default("max-backfills", "N", "1", "phases: concurrent transfers per OSD")
        .opt_default("domain-level", "LEVEL", "host", "phases: failure-domain level")
        .opt_default("domain-backfills", "N", "2", "phases: concurrent transfers per domain")
        .flag("quiet", "suppress the per-event log");
    let a = cli.parse(argv.iter())?;
    let seed = a.get_u64("seed")?.unwrap_or(0);
    let reduced = a.flag("reduced");
    let plan_cfg = plan_config_from(&a)?;
    let snapshot_dir = a.get("snapshot-dir").map(PathBuf::from);

    if let Some(path) = a.get("spec") {
        return run_spec_file(std::path::Path::new(path), a.flag("quiet"), snapshot_dir.as_deref());
    }

    let names: Vec<&str> = if a.flag("all") {
        equilibrium::scenario::ALL.to_vec()
    } else {
        match a.get("name") {
            Some(n) => vec![n],
            None => return Err(app_err!("one of --name or --all is required")),
        }
    };

    for name in names {
        let mut case = equilibrium::scenario::library::by_name(name, seed, reduced)
            .ok_or_else(|| app_err!("unknown scenario '{name}' (see `scenario list`)"))?;
        case.config.plan = plan_cfg.clone();
        case.config.snapshot_dir = snapshot_dir.clone();
        let var_before = case.state.utilization_variance();
        let outcome = case
            .run()
            .map_err(|e| app_err!("scenario '{name}' failed: {e}"))?;
        if !a.flag("quiet") {
            print!("{}", outcome.log.render());
        }
        println!(
            "{name}: {} moves ({}), variance {:.3e} -> {:.3e}, virtual time {}, calc {}",
            outcome.movements.len(),
            fmt_bytes_f(outcome.movements.iter().map(|m| m.bytes).sum::<u64>() as f64),
            var_before,
            case.state.utilization_variance(),
            fmt_duration(outcome.elapsed),
            fmt_duration(outcome.total_calc_seconds),
        );
        if plan_cfg.enabled() {
            println!(
                "  plan pipeline: {} planned -> {} executed ({} saved), {} phases over {} rounds",
                fmt_bytes_f(outcome.plan.raw_bytes as f64),
                fmt_bytes_f(outcome.plan.bytes as f64),
                fmt_bytes_f(outcome.plan.saved_bytes() as f64),
                outcome.plan.phases,
                outcome.plan.rounds,
            );
        }
        let problems = case.state.verify();
        if !problems.is_empty() {
            return Err(app_err!("scenario '{name}' violated invariants: {problems:?}"));
        }
        if let Some(dir) = a.get("out-dir") {
            report::scenario_series(std::path::Path::new(dir), name, &outcome.series)?;
        }
    }
    Ok(())
}

fn cmd_fleet(argv: &[String]) -> AppResult {
    let Some((which, rest)) = argv.split_first() else {
        return Err(app_err!("fleet requires an action: run|compare|gate"));
    };
    match which.as_str() {
        "run" => cmd_fleet_run(rest),
        "compare" => cmd_fleet_compare(rest),
        "gate" => cmd_fleet_gate(rest),
        other => Err(app_err!("unknown fleet action '{other}' (run|compare|gate)")),
    }
}

/// The sweep flags `fleet run` and `fleet compare` share.
fn fleet_cli(program: &'static str, about: &'static str) -> Cli {
    Cli::new(program, about)
        .opt("name", "NAME", "sweep one library scenario (default: the whole library)")
        .opt("seeds", "N", "seeds per scenario (default: 16, or 4 with --smoke)")
        .opt_default("seed-base", "N", "0", "first seed of the sweep")
        .flag("reduced", "reduced-size scenarios (small cluster and volumes)")
        .flag("smoke", "CI quick mode: implies --reduced, defaults --seeds to 4")
}

fn fleet_config_from(a: &equilibrium::util::cli::Args) -> AppResult<FleetConfig> {
    let smoke = a.flag("smoke");
    let seeds = match a.get_u64("seeds")? {
        Some(n) if n >= 1 => n,
        Some(_) => return Err(app_err!("--seeds must be ≥ 1")),
        None => {
            if smoke {
                4
            } else {
                16
            }
        }
    };
    Ok(FleetConfig {
        seeds,
        seed_base: a.get_u64("seed-base")?.unwrap_or(0),
        reduced: smoke || a.flag("reduced"),
        plan: plan_config_from(a)?,
        chunk: 1,
    })
}

fn fleet_names(a: &equilibrium::util::cli::Args) -> Vec<&str> {
    match a.get("name") {
        Some(n) => vec![n],
        None => equilibrium::scenario::ALL.to_vec(),
    }
}

fn size_label(reduced: bool) -> &'static str {
    if reduced {
        "reduced"
    } else {
        "full-size"
    }
}

/// Replay a spec JSON file on a fresh demo cluster under the standard
/// invariant suite (the `scenario run --spec` path; how promoted corpus
/// regressions are reproduced by hand).
fn run_spec_file(
    path: &std::path::Path,
    quiet: bool,
    snapshot_dir: Option<&std::path::Path>,
) -> AppResult {
    let spec = equilibrium::scenario::serde::load_file(path)
        .map_err(|e| app_err!("cannot replay '{}': {e}", path.display()))?;
    println!(
        "scenario: replaying spec '{}' ({} events, seed {})",
        spec.name,
        spec.events.len(),
        spec.seed,
    );
    let outcome = equilibrium::fuzz::replay_in(&spec, snapshot_dir);
    if !quiet {
        for v in &outcome.violations {
            println!("  violation {v}");
        }
    }
    if let Some(err) = &outcome.error {
        return Err(app_err!("spec '{}' aborted: {err}", spec.name));
    }
    if !outcome.violations.is_empty() {
        return Err(app_err!(
            "spec '{}' violated {} invariant(s)",
            spec.name,
            outcome.violations.len()
        ));
    }
    println!("clean: all invariants held across {} events", spec.events.len());
    Ok(())
}

fn cmd_fuzz(argv: &[String]) -> AppResult {
    let Some((which, rest)) = argv.split_first() else {
        return Err(app_err!("fuzz requires an action: run|gen"));
    };
    match which.as_str() {
        "run" => cmd_fuzz_run(rest),
        "gen" => cmd_fuzz_gen(rest),
        other => Err(app_err!("unknown fuzz action '{other}' (run|gen)")),
    }
}

/// Parse `--profile` into the profile list for a sweep (all four when
/// the flag is absent).
fn fuzz_profiles(a: &equilibrium::util::cli::Args) -> AppResult<Vec<equilibrium::fuzz::Profile>> {
    match a.get("profile") {
        None => Ok(equilibrium::fuzz::Profile::ALL.to_vec()),
        Some(name) => equilibrium::fuzz::Profile::parse(name).map(|p| vec![p]).ok_or_else(|| {
            app_err!(
                "unknown profile '{name}' (failure-heavy|churn-heavy|growth-heavy|kitchen-sink)"
            )
        }),
    }
}

fn cmd_fuzz_run(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium fuzz run", "chaos sweep through the invariant machine")
        .opt_default("cases", "N", "64", "generated scenario cases")
        .opt("seed-base", "N", "first case seed (default: 0xFA220000)")
        .opt("profile", "P", "sweep one weight profile (default: cycle all four)")
        .flag("reduced", "shorter timelines and smaller writes (CI smoke)")
        .opt_default("chunk", "N", "1", "parallel chunk length")
        .opt("out", "FILE", "write the report JSON here instead of stdout")
        .opt_default(
            "promote-dir",
            "DIR",
            "corpus/regressions",
            "where minimized failing specs are promoted",
        )
        .flag("quiet", "suppress the report on stdout");
    let a = cli.parse(argv.iter())?;
    let cfg = equilibrium::fuzz::FuzzConfig {
        cases: a.get_u64("cases")?.unwrap_or(64) as usize,
        seed_base: a.get_u64("seed-base")?.unwrap_or(0xFA22_0000),
        profiles: fuzz_profiles(&a)?,
        reduced: a.flag("reduced"),
        chunk: a.get_u64("chunk")?.unwrap_or(1).max(1) as usize,
    };
    println!(
        "fuzz: sweeping {} case(s) across {} profile(s) ({})",
        cfg.cases,
        cfg.profiles.len(),
        size_label(cfg.reduced),
    );
    let report = equilibrium::fuzz::run_sweep(&cfg);
    if let Some(path) = a.get("out") {
        std::fs::write(path, report.render())?;
        eprintln!("wrote {path}");
    } else if !a.flag("quiet") {
        print!("{}", report.render());
    }
    if report.is_clean() {
        return Ok(());
    }
    let dir = PathBuf::from(a.get_or("promote-dir", "corpus/regressions"));
    let paths = equilibrium::fuzz::promote(&dir, &report)?;
    for p in &paths {
        eprintln!("promoted {}", p.display());
    }
    Err(app_err!(
        "fuzz: {} failing case(s) with {} violation(s); minimized specs promoted to {}",
        report.failing.len(),
        report.violation_count(),
        dir.display(),
    ))
}

fn cmd_fuzz_gen(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium fuzz gen", "emit one generated scenario spec as JSON")
        .opt("seed", "N", "generation seed (required)")
        .opt_default("profile", "P", "kitchen-sink", "weight profile")
        .flag("reduced", "shorter timeline and smaller writes")
        .opt("out", "FILE", "write the spec here instead of stdout");
    let a = cli.parse(argv.iter())?;
    let seed = a.get_u64("seed")?.ok_or_else(|| app_err!("--seed is required"))?;
    let profile = fuzz_profiles(&a)?[0];
    let spec =
        equilibrium::fuzz::generate_spec(&clusters::demo(seed), seed, profile, a.flag("reduced"));
    let text = equilibrium::scenario::serde::dump(&spec);
    match a.get("out") {
        Some(path) => {
            std::fs::write(path, text)?;
            eprintln!("wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_estate(argv: &[String]) -> AppResult {
    let Some((which, rest)) = argv.split_first() else {
        return Err(app_err!("estate requires an action: list|run|report"));
    };
    match which.as_str() {
        "list" => {
            println!("estate cases (seeded, deterministic; see RFC 0008):");
            for name in equilibrium::estate::library::ALL {
                let case = equilibrium::estate::library::by_name(name, 0, true)
                    .expect("ALL names resolve");
                println!("  {name:<20} {}", case.description);
            }
            println!("routers: health (default), round-robin (baseline)");
            Ok(())
        }
        "run" => cmd_estate_run(rest),
        "report" => cmd_estate_report(rest),
        other => Err(app_err!("unknown estate action '{other}' (list|run|report)")),
    }
}

fn cmd_estate_run(argv: &[String]) -> AppResult {
    let cli = Cli::new("equilibrium estate run", "sweep estate cases under a router")
        .opt("name", "NAME", "estate case to sweep (see `estate list`)")
        .flag("all", "sweep every estate case")
        .opt_default("router", "NAME", "health", "routing policy (health|round-robin)")
        .opt("seeds", "N", "seeds per case (default: 8, or 4 with --smoke)")
        .opt_default("seed-base", "N", "0", "first seed of the sweep")
        .flag("reduced", "reduced-size members (small clusters; CI smoke)")
        .flag("smoke", "CI quick mode: implies --reduced, defaults --seeds to 4")
        .opt("out", "FILE", "write the estate baseline JSON (single --name only)")
        .opt("out-dir", "DIR", "write estate_summary.csv here")
        .flag("quiet", "suppress the summary table");
    let a = cli.parse(argv.iter())?;
    let smoke = a.flag("smoke");
    let reduced = smoke || a.flag("reduced");
    let seeds = match a.get_u64("seeds")? {
        Some(n) if n >= 1 => n,
        Some(_) => return Err(app_err!("--seeds must be ≥ 1")),
        None => {
            if smoke {
                4
            } else {
                8
            }
        }
    };
    let sweep_cfg = equilibrium::estate::EstateSweepConfig {
        seeds,
        seed_base: a.get_u64("seed-base")?.unwrap_or(0),
        chunk: 1,
    };
    let router = a.get_or("router", "health");
    let names: Vec<&str> = if a.flag("all") {
        equilibrium::estate::library::ALL.to_vec()
    } else {
        match a.get("name") {
            Some(n) => vec![n],
            None => return Err(app_err!("one of --name or --all is required")),
        }
    };
    if a.get("out").is_some() && names.len() != 1 {
        return Err(app_err!("--out pins one baseline; use it with a single --name"));
    }
    println!(
        "estate: sweeping {} case(s) × {} seeds ({}, {} router)",
        names.len(),
        sweep_cfg.seeds,
        size_label(reduced),
        router,
    );
    let mut baselines = Vec::new();
    for name in names {
        let case = equilibrium::estate::library::by_name(name, sweep_cfg.seed_base, reduced)
            .ok_or_else(|| app_err!("unknown estate case '{name}' (see `estate list`)"))?;
        let sweep = equilibrium::estate::sweep_spec(&case.spec, router, &case.config, &sweep_cfg)
            .map_err(|e| app_err!("estate sweep '{name}' failed: {e}"))?;
        baselines.push(sweep.summarize(sweep_cfg.seed_base));
    }
    if !a.flag("quiet") {
        println!("{}", report::estate_table(&baselines).render());
    }
    if let Some(path) = a.get("out") {
        std::fs::write(path, baselines[0].render())?;
        eprintln!("wrote {path}");
    }
    if let Some(dir) = a.get("out-dir") {
        report::write_estate_csv(std::path::Path::new(dir), &baselines)?;
    }
    Ok(())
}

fn cmd_estate_report(argv: &[String]) -> AppResult {
    let cli = Cli::new(
        "equilibrium estate report",
        "render estate baselines side by side (one row per case × router)",
    )
    .opt("baseline", "FILES", "comma-separated estate baseline JSON files (required)")
    .opt("out-dir", "DIR", "write estate_summary.csv here");
    let a = cli.parse(argv.iter())?;
    let paths = a
        .get("baseline")
        .ok_or_else(|| app_err!("--baseline is required"))?;
    let mut baselines = Vec::new();
    for path in paths.split(',').filter(|p| !p.is_empty()) {
        let b = equilibrium::estate::parse_estate_baseline(&std::fs::read_to_string(path)?)
            .map_err(|e| app_err!("cannot load estate baseline '{path}': {e}"))?;
        baselines.push(b);
    }
    if baselines.is_empty() {
        return Err(app_err!("--baseline names no files"));
    }
    println!(
        "Estate summary — {} baseline(s), {} seeds each",
        baselines.len(),
        baselines[0].seeds,
    );
    println!("{}", report::estate_table(&baselines).render());
    if let Some(dir) = a.get("out-dir") {
        report::write_estate_csv(std::path::Path::new(dir), &baselines)?;
    }
    Ok(())
}

fn cmd_fleet_run(argv: &[String]) -> AppResult {
    let cli = fleet_cli("equilibrium fleet run", "deterministic multi-seed scenario sweep")
        .flag("optimize", "run each round's plan through the optimizer (RFC 0003)")
        .flag("phases", "execute plans in failure-domain-capped phases (implies --optimize)")
        .opt_default("max-backfills", "N", "1", "phases: concurrent transfers per OSD")
        .opt_default("domain-level", "LEVEL", "host", "phases: failure-domain level")
        .opt_default("domain-backfills", "N", "2", "phases: concurrent transfers per domain")
        .opt("out", "FILE", "write the sweep summary as FLEET baseline JSON")
        .opt("out-dir", "DIR", "write fleet_summary.csv here")
        .opt("checkpoint", "DIR", "persist completed (scenario, seed) cells here (create or continue)")
        .opt("resume", "DIR", "continue an existing checkpoint (must match the sweep flags)")
        .opt("max-cells", "N", "stop after computing N new cells (requires --checkpoint/--resume)")
        .flag("quiet", "suppress the summary table");
    let a = cli.parse(argv.iter())?;
    let cfg = fleet_config_from(&a)?;
    let names = fleet_names(&a);
    let checkpoint = match (a.get("checkpoint"), a.get("resume")) {
        (Some(_), Some(_)) => {
            return Err(app_err!("--checkpoint and --resume are mutually exclusive"))
        }
        (Some(dir), None) => Some(fleet::CheckpointConfig {
            dir: PathBuf::from(dir),
            max_cells: a.get_u64("max-cells")?,
            resume: false,
        }),
        (None, Some(dir)) => Some(fleet::CheckpointConfig {
            dir: PathBuf::from(dir),
            max_cells: a.get_u64("max-cells")?,
            resume: true,
        }),
        (None, None) => {
            if a.get("max-cells").is_some() {
                return Err(app_err!("--max-cells requires --checkpoint or --resume"));
            }
            None
        }
    };
    println!(
        "fleet: sweeping {} scenario(s) × {} seeds ({}, {} pipeline)",
        names.len(),
        cfg.seeds,
        size_label(cfg.reduced),
        cfg.pipeline_label(),
    );
    let result = match &checkpoint {
        None => fleet::run_library(&names, &cfg).map_err(|e| app_err!("fleet sweep failed: {e}"))?,
        Some(ck) => {
            let run = fleet::run_library_checkpointed(&names, &cfg, ck)
                .map_err(|e| app_err!("fleet sweep failed: {e}"))?;
            eprintln!(
                "checkpoint {}: {} cell(s) reused, {} computed, {} remaining",
                ck.dir.display(),
                run.reused,
                run.computed,
                run.skipped,
            );
            match run.result {
                Some(result) => result,
                None => {
                    // deliberate exit 0: an exhausted --max-cells budget
                    // is the expected way to slice a long sweep
                    println!(
                        "sweep incomplete ({}/{} cells done) — continue with \
                         `fleet run --resume {}` plus the same sweep flags",
                        run.total - run.skipped,
                        run.total,
                        ck.dir.display(),
                    );
                    return Ok(());
                }
            }
        }
    };
    let baseline = result.to_baseline();
    if !a.flag("quiet") {
        println!("{}", report::fleet_table(&baseline).render());
        println!("mean calc time per run: {}", fmt_duration(result.mean_calc_seconds()));
    }
    if let Some(path) = a.get("out") {
        std::fs::write(path, baseline.render())?;
        eprintln!("wrote {path}");
    }
    if let Some(dir) = a.get("out-dir") {
        report::write_fleet_csv(std::path::Path::new(dir), &baseline)?;
    }
    Ok(())
}

fn cmd_fleet_compare(argv: &[String]) -> AppResult {
    let cli = fleet_cli(
        "equilibrium fleet compare",
        "sweep raw vs optimized+phased pipelines side by side, or --balancers for a balancer bake-off",
    )
    .opt(
        "balancers",
        "A,B,..",
        "bake-off mode: sweep every named balancer engine (equilibrium|mgr|asura|bounded|reference)",
    )
    .opt("out", "FILE", "bake-off: write the summary as compare baseline JSON")
    .opt("out-dir", "DIR", "bake-off: write bakeoff_summary.csv here")
    .flag("quiet", "suppress the summary table");
    let a = cli.parse(argv.iter())?;
    let mut cfg = fleet_config_from(&a)?;
    let names = fleet_names(&a);
    if let Some(list) = a.get("balancers") {
        let balancers: Vec<&str> = list.split(',').filter(|b| !b.is_empty()).collect();
        if balancers.is_empty() {
            return Err(app_err!("--balancers names no engines"));
        }
        println!(
            "fleet compare: {} balancer(s) × {} scenario(s) × {} seeds ({}, {} pipeline)",
            balancers.len(),
            names.len(),
            cfg.seeds,
            size_label(cfg.reduced),
            cfg.pipeline_label(),
        );
        let result = fleet::run_compare(&balancers, &names, &cfg)
            .map_err(|e| app_err!("bake-off sweep failed: {e}"))?;
        let baseline = result.to_baseline();
        if !a.flag("quiet") {
            println!("{}", report::compare_table(&baseline).render());
        }
        if let Some(path) = a.get("out") {
            std::fs::write(path, baseline.render())?;
            eprintln!("wrote {path}");
        }
        if let Some(dir) = a.get("out-dir") {
            report::write_compare_csv(std::path::Path::new(dir), &baseline)?;
        }
        return Ok(());
    }
    println!(
        "fleet compare: {} scenario(s) × {} seeds ({}) — raw vs phased pipeline",
        names.len(),
        cfg.seeds,
        size_label(cfg.reduced),
    );
    cfg.plan = PlanConfig::default();
    let raw = fleet::run_library(&names, &cfg)
        .map_err(|e| app_err!("raw sweep failed: {e}"))?
        .to_baseline();
    cfg.plan = PlanConfig::phased();
    let piped = fleet::run_library(&names, &cfg)
        .map_err(|e| app_err!("phased sweep failed: {e}"))?
        .to_baseline();
    let mut t = report::Table::new(&[
        "Scenario",
        "Moved p50 raw",
        "Exec p50 piped",
        "Saved p50",
        "Phases p50",
        "Makespan p50 raw",
        "Makespan p50 piped",
    ]);
    for (r, p) in raw.scenarios.iter().zip(&piped.scenarios) {
        let g = |s: &equilibrium::fleet::ScenarioDist, m: &str| {
            s.metrics.get(m).copied().unwrap_or_default()
        };
        let moved = g(r, "raw_bytes").p50;
        let exec = g(p, "executed_bytes").p50;
        t.push_row(vec![
            r.name.clone(),
            fmt_bytes_f(moved),
            fmt_bytes_f(exec),
            // signed: executing more than planned must be visible
            fmt_bytes_f(moved - exec),
            format!("{:.0}", g(p, "phases").p50),
            fmt_duration(g(r, "makespan").p50),
            fmt_duration(g(p, "makespan").p50),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_fleet_gate(argv: &[String]) -> AppResult {
    let cli = Cli::new(
        "equilibrium fleet gate",
        "replay a sweep and diff it against a committed statistical baseline",
    )
    .opt("baseline", "FILE", "committed FLEET baseline JSON (required)")
    .opt_default("rel", "X", "0.01", "relative tolerance per metric field");
    let a = cli.parse(argv.iter())?;
    let path = a
        .get("baseline")
        .ok_or_else(|| app_err!("--baseline is required"))?;
    let baseline = fleet::parse_baseline(&std::fs::read_to_string(path)?)
        .map_err(|e| app_err!("cannot load baseline '{path}': {e}"))?;
    // replay under exactly the sweep parameters the baseline records —
    // including the scheduler knobs for phased pipelines (phase counts
    // and makespans depend on them)
    let plan = match baseline.meta.pipeline.as_str() {
        "raw" => PlanConfig::default(),
        "optimized" => PlanConfig::optimized(),
        "phased" => {
            let sm = baseline
                .meta
                .schedule
                .as_ref()
                .ok_or_else(|| app_err!("phased baseline lacks its scheduler parameters"))?;
            let level = Level::parse(&sm.domain_level).ok_or_else(|| {
                app_err!("baseline has unknown failure-domain level '{}'", sm.domain_level)
            })?;
            let osd_cap = sm.max_backfills_per_osd as usize;
            PlanConfig {
                optimize: true,
                schedule: Some(ScheduleConfig {
                    max_backfills_per_osd: osd_cap,
                    domain_level: level,
                    max_backfills_per_domain: sm.max_backfills_per_domain as usize,
                    // mirror plan_config_from: the makespan model simulates
                    // the same per-OSD concurrency the phases are packed for
                    executor: ExecutorConfig { max_backfills: osd_cap, ..ExecutorConfig::default() },
                    ..ScheduleConfig::default()
                }),
            }
        }
        other => return Err(app_err!("baseline has unknown pipeline '{other}'")),
    };
    let cfg = FleetConfig {
        seeds: baseline.meta.seeds,
        seed_base: baseline.meta.seed_base,
        reduced: baseline.meta.reduced,
        plan,
        chunk: 1,
    };
    let names: Vec<&str> = baseline.scenarios.iter().map(|s| s.name.as_str()).collect();
    println!(
        "fleet gate: replaying {} scenario(s) × {} seeds against {path}",
        names.len(),
        cfg.seeds,
    );
    let current = fleet::run_library(&names, &cfg)
        .map_err(|e| app_err!("fleet sweep failed: {e}"))?
        .to_baseline();
    let gate_cfg = GateConfig { rel: a.get_f64("rel")?.unwrap_or(0.01), ..GateConfig::default() };
    let outcome = fleet::gate(&baseline, &current, &gate_cfg);
    for m in &outcome.mismatches {
        eprintln!("mismatch: {m}");
    }
    for v in &outcome.violations {
        eprintln!("violation: {v}");
    }
    if outcome.passed() {
        println!(
            "gate OK: {} metric fields within tolerance (rel {})",
            outcome.checked, gate_cfg.rel
        );
        Ok(())
    } else {
        Err(app_err!(
            "fleet gate FAILED: {} mismatch(es), {} violation(s) over {} checked fields",
            outcome.mismatches.len(),
            outcome.violations.len(),
            outcome.checked
        ))
    }
}

fn cmd_runtime_info() -> AppResult {
    let dir = equilibrium::runtime::default_artifact_dir();
    println!("artifact dir: {}", dir.display());
    if !Runtime::artifacts_present(&dir) {
        println!("no artifacts found — run `make artifacts`");
        return Ok(());
    }
    let rt = Runtime::load(&dir)?;
    println!("PJRT CPU client OK; compiled buckets: {:?}", rt.buckets());
    let used = vec![900.0, 100.0, 500.0, 500.0];
    let size = vec![1000.0; 4];
    let mask = vec![true; 4];
    let (var_before, var_after) = rt.score_padded(&used, &size, &mask, 0, 200.0)?;
    println!(
        "smoke score: var_before={var_before:.6}, best candidate = osd.1 ({:.6})",
        var_after[1]
    );
    Ok(())
}
