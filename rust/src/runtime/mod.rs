//! Runtime layer: PJRT loading/execution of the AOT-compiled JAX/Pallas
//! scoring artifacts, and the XLA-backed scoring backend.
//!
//! Python runs only at build time (`make artifacts`); the request path is
//! pure Rust + the PJRT C API.

pub mod pjrt;
pub mod xla_scorer;

pub use pjrt::{default_artifact_dir, Runtime, ScoreExecutable, SIZE_BUCKETS};
pub use xla_scorer::XlaScorer;
