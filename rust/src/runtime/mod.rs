//! Runtime layer: PJRT loading/execution of the AOT-compiled JAX/Pallas
//! scoring artifacts, and the XLA-backed scoring backend.
//!
//! Python runs only at build time (`make artifacts`); the request path is
//! pure Rust + the PJRT C API. The real implementation needs the external
//! `xla` crate, which the offline build does not vendor — it compiles only
//! with the `xla` cargo feature. Without the feature an API-compatible
//! stub (`runtime/stub.rs`) is used instead: artifact probing reports
//! "absent" and loading returns a [`RuntimeError`], so callers that guard
//! on `Runtime::artifacts_present` degrade gracefully.

use std::fmt;
use std::path::PathBuf;

/// Errors from the scoring runtime (artifact loading, PJRT execution,
/// or — in stub builds — the runtime being compiled out).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// `Result` alias for runtime operations.
pub type RuntimeResult<T> = std::result::Result<T, RuntimeError>;

/// Default artifact directory: `$EQUILIBRIUM_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("EQUILIBRIUM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// The size buckets `aot.py` compiles (keep in sync with
/// `python/compile/model.py::SIZE_BUCKETS`).
pub const SIZE_BUCKETS: &[usize] = &[256, 1024, 4096];

#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(feature = "xla")]
pub mod xla_scorer;
#[cfg(feature = "xla")]
pub use pjrt::{Runtime, ScoreExecutable};
#[cfg(feature = "xla")]
pub use xla_scorer::XlaScorer;

#[cfg(not(feature = "xla"))]
pub mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{Runtime, ScoreExecutable, XlaScorer};
