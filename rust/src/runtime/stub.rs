//! API-compatible stub of the PJRT runtime, compiled when the `xla`
//! cargo feature is off (the offline build cannot vendor the `xla`
//! crate).
//!
//! The types can never be constructed (they carry an [`Infallible`]
//! field), so every method body is statically unreachable; the
//! constructors return [`RuntimeError`] and
//! [`Runtime::artifacts_present`] reports `false`, which makes every
//! artifact-guarded test/bench skip cleanly.

use std::convert::Infallible;
use std::path::Path;

use crate::balancer::scoring::{MoveScorer, ScoreRequest, ScoreResponse};

use super::{RuntimeError, RuntimeResult};

fn unavailable() -> RuntimeError {
    RuntimeError(
        "XLA runtime not compiled in (vendor the `xla` crate, add it to Cargo.toml, \
         and build with `--features xla`)"
            .to_string(),
    )
}

/// Stub of one compiled scoring executable (never constructible).
pub struct ScoreExecutable {
    /// Padded lane count of the compiled graph.
    pub padded: usize,
    _never: Infallible,
}

impl ScoreExecutable {
    /// Execute the scoring graph (statically unreachable in stub builds).
    pub fn run(
        &self,
        _used: &[f64],
        _size: &[f64],
        _mask: &[f64],
        _valid: &[f64],
        _src: usize,
        _shard: f64,
    ) -> RuntimeResult<(f64, Vec<f64>)> {
        match self._never {}
    }
}

/// Stub of the PJRT runtime (never constructible).
pub struct Runtime {
    _never: Infallible,
}

impl Runtime {
    /// Always fails: the runtime is compiled out.
    pub fn load(_dir: &Path) -> RuntimeResult<Runtime> {
        Err(unavailable())
    }

    /// Always fails: the runtime is compiled out.
    pub fn load_default() -> RuntimeResult<Runtime> {
        Err(unavailable())
    }

    /// Without the `xla` feature no artifact can ever be used, so none
    /// are reported present.
    pub fn artifacts_present(_dir: &Path) -> bool {
        false
    }

    /// The executable for the smallest bucket ≥ `n` (unreachable).
    pub fn bucket_for(&self, _n: usize) -> RuntimeResult<&ScoreExecutable> {
        match self._never {}
    }

    /// Available bucket sizes (unreachable).
    pub fn buckets(&self) -> Vec<usize> {
        match self._never {}
    }

    /// Score with automatic padding (unreachable).
    pub fn score_padded(
        &self,
        _used: &[f64],
        _size: &[f64],
        _mask: &[bool],
        _src: usize,
        _shard: f64,
    ) -> RuntimeResult<(f64, Vec<f64>)> {
        match self._never {}
    }
}

/// Stub of the XLA-backed [`MoveScorer`] (never constructible).
pub struct XlaScorer {
    _never: Infallible,
}

impl XlaScorer {
    /// Wrap a runtime (unreachable: no `Runtime` can exist).
    pub fn new(rt: Runtime) -> XlaScorer {
        match rt._never {}
    }

    /// Always fails: the runtime is compiled out.
    pub fn load_default() -> RuntimeResult<XlaScorer> {
        Err(unavailable())
    }
}

impl MoveScorer for XlaScorer {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn score(&mut self, _req: &ScoreRequest<'_>) -> ScoreResponse {
        match self._never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_absent_and_fails_to_load() {
        assert!(!Runtime::artifacts_present(Path::new("artifacts")));
        assert!(Runtime::load_default().is_err());
        assert!(XlaScorer::load_default().is_err());
        let msg = XlaScorer::load_default().unwrap_err().to_string();
        assert!(msg.contains("`xla`"), "{msg}");
    }
}
