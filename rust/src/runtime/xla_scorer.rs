//! The XLA-backed [`MoveScorer`]: Equilibrium's scoring hot-spot served
//! by the AOT-compiled JAX/Pallas kernel through PJRT.
//!
//! Drop-in replacement for `NativeScorer` (`--scoring xla` on the CLI);
//! the parity test below pins both backends together, which transitively
//! anchors the Rust implementation to the Python oracle (`ref.py` ←
//! pytest → Pallas kernel ← aot.py/HLO → this scorer).

use crate::balancer::scoring::{MoveScorer, ScoreRequest, ScoreResponse};

use super::pjrt::Runtime;
use super::RuntimeResult;

/// Scorer backed by the PJRT runtime. Reuses pre-allocated padding
/// buffers across calls (the balancer calls this once per candidate
/// shard, thousands of times per plan).
pub struct XlaScorer {
    rt: Runtime,
    /// scratch, kept across calls to avoid re-allocation
    used: Vec<f64>,
    size: Vec<f64>,
    mask: Vec<f64>,
    valid: Vec<f64>,
}

impl XlaScorer {
    pub fn new(rt: Runtime) -> XlaScorer {
        XlaScorer { rt, used: Vec::new(), size: Vec::new(), mask: Vec::new(), valid: Vec::new() }
    }

    /// Construct from the default artifact directory.
    pub fn load_default() -> RuntimeResult<XlaScorer> {
        Ok(XlaScorer::new(Runtime::load_default()?))
    }
}

impl MoveScorer for XlaScorer {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn score(&mut self, req: &ScoreRequest<'_>) -> ScoreResponse {
        let n = req.used.len();
        let exe = self
            .rt
            .bucket_for(n)
            .expect("no artifact bucket large enough for this cluster");
        let p = exe.padded;
        self.used.clear();
        self.used.extend_from_slice(req.used);
        self.used.resize(p, 0.0);
        self.size.clear();
        self.size.extend_from_slice(req.size);
        self.size.resize(p, 0.0);
        self.mask.clear();
        self.mask.extend(req.mask.iter().map(|&m| if m { 1.0 } else { 0.0 }));
        self.mask.resize(p, 0.0);
        self.valid.clear();
        self.valid.resize(n, 1.0);
        self.valid.resize(p, 0.0);

        let (var_before, mut var_after) = exe
            .run(&self.used, &self.size, &self.mask, &self.valid, req.src, req.shard)
            .expect("PJRT execution failed");
        var_after.truncate(n);
        ScoreResponse { var_before, var_after }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::scoring::{score_naive, NativeScorer};
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn scorer() -> Option<XlaScorer> {
        let dir = PathBuf::from("artifacts");
        if !Runtime::artifacts_present(&dir) {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(XlaScorer::new(Runtime::load(&dir).unwrap()))
    }

    #[test]
    fn xla_matches_native_backend() {
        let Some(mut xla) = scorer() else { return };
        let mut native = NativeScorer;
        let mut rng = Rng::new(2024);
        for case in 0..20 {
            let n = 2 + rng.index(500);
            let size: Vec<f64> = (0..n).map(|_| rng.range_f64(1e12, 2e13)).collect();
            let used: Vec<f64> = size.iter().map(|&s| s * rng.range_f64(0.1, 0.9)).collect();
            let src = rng.index(n);
            let shard = used[src] * rng.range_f64(0.01, 0.5);
            let mask: Vec<bool> = (0..n).map(|_| rng.chance(0.7)).collect();
            let req = ScoreRequest { used: &used, size: &size, src, shard, mask: &mask };

            let a = xla.score(&req);
            let b = native.score(&req);
            assert!(
                (a.var_before - b.var_before).abs() <= 1e-12 + 1e-9 * b.var_before.abs(),
                "case {case}: var_before {} vs {}",
                a.var_before,
                b.var_before
            );
            for j in 0..n {
                let (x, y) = (a.var_after[j], b.var_after[j]);
                if x.is_infinite() || y.is_infinite() {
                    assert_eq!(x.is_infinite(), y.is_infinite(), "case {case} slot {j}");
                } else {
                    assert!(
                        (x - y).abs() <= 1e-12 + 1e-9 * y.abs(),
                        "case {case} slot {j}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn xla_matches_naive_reference() {
        let Some(mut xla) = scorer() else { return };
        let used = vec![9e12, 5e11, 5e12, 5e12, 5e12];
        let size = vec![1e13, 1e12, 1e13, 1e13, 1e13];
        let mask = vec![true; 5];
        let req = ScoreRequest { used: &used, size: &size, src: 0, shard: 1e11, mask: &mask };
        let a = xla.score(&req);
        let b = score_naive(&req);
        for j in 0..5 {
            let (x, y) = (a.var_after[j], b.var_after[j]);
            if !x.is_infinite() {
                assert!((x - y).abs() < 1e-9 * y.abs() + 1e-15, "slot {j}: {x} vs {y}");
            }
        }
    }
}
