//! PJRT runtime: load and execute the AOT-compiled scoring artifacts.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (see `python/compile/aot.py`).
//! Python never runs here — the artifacts are produced once at build
//! time by `make artifacts`. Compiled only with the `xla` cargo feature;
//! see `runtime/stub.rs` for the offline stand-in.

use std::path::Path;

use super::{RuntimeError, RuntimeResult, SIZE_BUCKETS};

/// One compiled scoring executable for a fixed padded size.
pub struct ScoreExecutable {
    /// Padded lane count of the compiled graph.
    pub padded: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl ScoreExecutable {
    /// Load `score_moves_<padded>.hlo.txt` and compile it on `client`.
    pub fn load(client: &xla::PjRtClient, dir: &Path, padded: usize) -> RuntimeResult<ScoreExecutable> {
        let path = dir.join(format!("score_moves_{padded}.hlo.txt"));
        let path_str = path
            .to_str()
            .ok_or_else(|| RuntimeError("non-utf8 artifact path".to_string()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| RuntimeError(format!("loading HLO text from {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| RuntimeError(format!("compiling {}: {e}", path.display())))?;
        Ok(ScoreExecutable { padded, exe })
    }

    /// Execute the scoring graph. All slices must have length `padded`.
    /// Returns `(var_before, var_after)`.
    pub fn run(
        &self,
        used: &[f64],
        size: &[f64],
        mask: &[f64],
        valid: &[f64],
        src: usize,
        shard: f64,
    ) -> RuntimeResult<(f64, Vec<f64>)> {
        for (name, v) in [("used", used), ("size", size), ("mask", mask), ("valid", valid)] {
            if v.len() != self.padded {
                return Err(RuntimeError(format!(
                    "input '{name}' has length {} but executable is padded to {}",
                    v.len(),
                    self.padded
                )));
            }
        }
        let params = [src as f64, shard];
        let inputs = [
            xla::Literal::vec1(used),
            xla::Literal::vec1(size),
            xla::Literal::vec1(mask),
            xla::Literal::vec1(valid),
            xla::Literal::vec1(&params),
        ];
        fn rt_err<E: std::fmt::Display>(what: &str) -> impl Fn(E) -> RuntimeError + '_ {
            move |e| RuntimeError(format!("{what}: {e}"))
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&inputs)
            .map_err(rt_err("PJRT execute"))?[0][0]
            .to_literal_sync()
            .map_err(rt_err("PJRT literal sync"))?;
        // lowered with return_tuple=True → tuple(var_before[1], var_after[N])
        let (var_before_lit, var_after_lit) =
            result.to_tuple2().map_err(rt_err("decoding result tuple"))?;
        let var_before = var_before_lit
            .to_vec::<f64>()
            .map_err(rt_err("decoding var_before"))?[0];
        let var_after = var_after_lit
            .to_vec::<f64>()
            .map_err(rt_err("decoding var_after"))?;
        Ok((var_before, var_after))
    }
}

/// The runtime: a PJRT CPU client plus the compiled size buckets.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    executables: Vec<ScoreExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and compile every artifact found in
    /// `dir`. Fails if no bucket is available.
    pub fn load(dir: &Path) -> RuntimeResult<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| RuntimeError(format!("creating PJRT CPU client: {e}")))?;
        let mut executables = Vec::new();
        for &n in SIZE_BUCKETS {
            if dir.join(format!("score_moves_{n}.hlo.txt")).exists() {
                executables.push(ScoreExecutable::load(&client, dir, n)?);
            }
        }
        if executables.is_empty() {
            return Err(RuntimeError(format!(
                "no score_moves_*.hlo.txt artifacts in {} — run `make artifacts`",
                dir.display()
            )));
        }
        executables.sort_by_key(|e| e.padded);
        Ok(Runtime { client, executables })
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> RuntimeResult<Runtime> {
        Self::load(&super::default_artifact_dir())
    }

    /// Are artifacts available without constructing a client?
    pub fn artifacts_present(dir: &Path) -> bool {
        SIZE_BUCKETS
            .iter()
            .any(|n| dir.join(format!("score_moves_{n}.hlo.txt")).exists())
    }

    /// The executable for the smallest bucket ≥ `n`.
    pub fn bucket_for(&self, n: usize) -> RuntimeResult<&ScoreExecutable> {
        self.executables
            .iter()
            .find(|e| e.padded >= n)
            .ok_or_else(|| {
                RuntimeError(format!(
                    "cluster has {n} OSDs but largest compiled bucket is {}",
                    self.executables.last().map(|e| e.padded).unwrap_or(0)
                ))
            })
    }

    /// Available bucket sizes (ascending).
    pub fn buckets(&self) -> Vec<usize> {
        self.executables.iter().map(|e| e.padded).collect()
    }

    /// Score with automatic padding: pads `used/size/mask` to the bucket
    /// size, marks real lanes valid, and truncates the result back to
    /// `n = used.len()`.
    pub fn score_padded(
        &self,
        used: &[f64],
        size: &[f64],
        mask: &[bool],
        src: usize,
        shard: f64,
    ) -> RuntimeResult<(f64, Vec<f64>)> {
        let n = used.len();
        let exe = self.bucket_for(n)?;
        let p = exe.padded;
        let mut pu = vec![0.0; p];
        let mut ps = vec![0.0; p];
        let mut pm = vec![0.0; p];
        let mut pv = vec![0.0; p];
        pu[..n].copy_from_slice(used);
        ps[..n].copy_from_slice(size);
        for i in 0..n {
            pm[i] = if mask[i] { 1.0 } else { 0.0 };
            pv[i] = 1.0;
        }
        let (var_before, mut var_after) = exe.run(&pu, &ps, &pm, &pv, src, shard)?;
        var_after.truncate(n);
        Ok((var_before, var_after))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> PathBuf {
        // tests run from the crate root
        PathBuf::from("artifacts")
    }

    #[test]
    fn loads_and_scores() {
        if !Runtime::artifacts_present(&artifacts()) {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::load(&artifacts()).unwrap();
        assert!(!rt.buckets().is_empty());
        let used = vec![900.0, 100.0, 500.0, 500.0];
        let size = vec![1000.0; 4];
        let mask = vec![true; 4];
        let (var_before, var_after) = rt.score_padded(&used, &size, &mask, 0, 200.0).unwrap();
        assert!(var_before > 0.0);
        assert!(var_after[0].is_infinite(), "source is excluded");
        assert!(var_after[1] < var_before, "equalizing move improves variance");
        assert!(var_after[1] < var_after[2]);
    }

    #[test]
    fn bucket_selection() {
        if !Runtime::artifacts_present(&artifacts()) {
            return;
        }
        let rt = Runtime::load(&artifacts()).unwrap();
        let b = rt.bucket_for(300).unwrap();
        assert!(b.padded >= 300);
        assert!(rt.bucket_for(1_000_000).is_err());
    }

    #[test]
    fn rejects_wrong_lengths() {
        if !Runtime::artifacts_present(&artifacts()) {
            return;
        }
        let rt = Runtime::load(&artifacts()).unwrap();
        let exe = rt.bucket_for(1).unwrap();
        let bad = vec![0.0; 3];
        assert!(exe.run(&bad, &bad, &bad, &bad, 0, 1.0).is_err());
    }
}
