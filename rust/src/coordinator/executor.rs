//! Movement executor: discrete-event simulation of actually *carrying
//! out* a movement plan on a cluster, with Ceph-style backfill
//! throttling.
//!
//! The balancers answer "which shards should move"; this component
//! answers "how long will the data movement take and how do we keep it
//! from starving client I/O". It models Ceph's `osd_max_backfills` (at
//! most `max_backfills` concurrent transfers touching any one OSD, as
//! source or destination) and a per-transfer recovery bandwidth. The
//! paper argues the planning-time investment is negligible because
//! "storage movements of several terabytes require more time than
//! planning" — this executor quantifies that claim (EXPERIMENTS.md).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::fmt;

use crate::cluster::Movement;
use crate::crush::OsdId;

/// A plan handed to [`execute_plan`] referenced a device the cluster
/// does not have. Returned instead of an index panic so callers feeding
/// externally-sourced plans (snapshots, CLI input, estate routing) can
/// surface the offending movement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecutorError {
    /// `plan[index]` names an OSD id ≥ the cluster's device count.
    OsdOutOfRange {
        /// Position of the offending movement in the plan.
        index: usize,
        /// The out-of-range device id.
        osd: OsdId,
        /// Number of devices the executor was told the cluster has.
        osd_count: usize,
    },
}

impl fmt::Display for ExecutorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecutorError::OsdOutOfRange { index, osd, osd_count } => write!(
                f,
                "plan[{index}] references osd.{osd} but the cluster has {osd_count} devices"
            ),
        }
    }
}

impl std::error::Error for ExecutorError {}

/// Executor tunables.
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Max concurrent transfers per OSD (Ceph default osd_max_backfills=1).
    pub max_backfills: usize,
    /// Per-transfer throughput, bytes/second (HDD-ish default 100 MiB/s).
    pub bandwidth: f64,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig { max_backfills: 1, bandwidth: 100.0 * (1 << 20) as f64 }
    }
}

/// Completed-transfer record.
#[derive(Debug, Clone)]
pub struct TransferRecord {
    pub movement: Movement,
    /// Virtual start time, seconds.
    pub start: f64,
    /// Virtual completion time, seconds.
    pub finish: f64,
}

/// Result of executing a plan.
#[derive(Debug, Clone)]
pub struct ExecutionReport {
    pub transfers: Vec<TransferRecord>,
    /// Virtual wall-clock of the whole plan, seconds.
    pub makespan: f64,
    /// Peak number of simultaneous transfers.
    pub peak_concurrency: usize,
    pub total_bytes: u64,
    /// Per-OSD transfer-lane occupancy, seconds: every transfer adds its
    /// duration to both endpoints. Shows which devices bound a batch —
    /// the makespan is at least `max(osd_busy_seconds) / max_backfills`.
    pub osd_busy_seconds: Vec<f64>,
}

impl ExecutionReport {
    /// Aggregate achieved throughput, bytes/second.
    pub fn throughput(&self) -> f64 {
        if self.makespan > 0.0 {
            self.total_bytes as f64 / self.makespan
        } else {
            0.0
        }
    }

    /// The OSD whose transfer lanes were occupied longest (the batch's
    /// bottleneck device), with its busy seconds. None for empty plans.
    ///
    /// Total-order comparison (`f64::total_cmp`), so non-finite busy
    /// seconds — e.g. +∞ from a zero-bandwidth config — rank as the
    /// bottleneck instead of panicking, and NaN lanes (excluded by the
    /// `> 0.0` occupancy filter anyway) can never poison the fold.
    /// Tie-break: equal busy seconds → lowest OSD id.
    pub fn bottleneck(&self) -> Option<(OsdId, f64)> {
        self.osd_busy_seconds
            .iter()
            .enumerate()
            .filter(|&(_, &b)| b > 0.0)
            .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(o, &b)| (o as OsdId, b))
    }
}

#[derive(Debug, PartialEq)]
struct Finish {
    time: f64,
    idx: usize,
}

impl Eq for Finish {}

impl PartialOrd for Finish {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Finish {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // total order: a non-finite duration (degenerate bandwidth
        // config) must not panic the event heap
        self.time.total_cmp(&other.time).then(self.idx.cmp(&other.idx))
    }
}

/// Execute `plan` (in order, FIFO per constraint) under the config's
/// concurrency limits. Movements are started greedily: at every event
/// time the earliest-planned movement whose source and destination both
/// have a free backfill slot starts.
///
/// Degenerate plans are handled explicitly rather than by index math:
///
/// - A movement referencing an OSD id ≥ `osd_count` yields
///   [`ExecutorError::OsdOutOfRange`] (the whole plan is rejected before
///   any virtual time passes).
/// - A self-move (`from == to`) transfers no data, so it is *skipped*:
///   it produces no [`TransferRecord`], occupies no backfill slot or
///   busy seconds on the device, and its bytes are excluded from
///   `total_bytes`. (Counting it would double-book one OSD's inflight
///   slots and busy lanes for a transfer that cannot physically occur.)
pub fn execute_plan(
    plan: &[Movement],
    cfg: &ExecutorConfig,
    osd_count: usize,
) -> Result<ExecutionReport, ExecutorError> {
    for (index, m) in plan.iter().enumerate() {
        for osd in [m.from, m.to] {
            if osd as usize >= osd_count {
                return Err(ExecutorError::OsdOutOfRange { index, osd, osd_count });
            }
        }
    }
    let mut inflight_per_osd: Vec<usize> = vec![0; osd_count];
    let mut busy_per_osd: Vec<f64> = vec![0.0; osd_count];
    // indices in plan order; self-moves transfer nothing and are skipped
    let mut pending: Vec<usize> = (0..plan.len()).filter(|&i| plan[i].from != plan[i].to).collect();
    let mut finish_heap: BinaryHeap<Reverse<Finish>> = BinaryHeap::new();
    let mut transfers: Vec<TransferRecord> = Vec::with_capacity(pending.len());
    let mut now = 0.0f64;
    let mut running = 0usize;
    let mut peak = 0usize;
    let mut started = vec![false; plan.len()];

    let slot_free = |inflight: &[usize], osd: OsdId, cfg: &ExecutorConfig| {
        inflight[osd as usize] < cfg.max_backfills
    };

    loop {
        // start everything startable at `now`, in plan order
        let mut made_progress = true;
        while made_progress {
            made_progress = false;
            for &i in &pending {
                if started[i] {
                    continue;
                }
                let m = &plan[i];
                if slot_free(&inflight_per_osd, m.from, cfg)
                    && slot_free(&inflight_per_osd, m.to, cfg)
                {
                    started[i] = true;
                    inflight_per_osd[m.from as usize] += 1;
                    inflight_per_osd[m.to as usize] += 1;
                    running += 1;
                    peak = peak.max(running);
                    let dur = m.bytes as f64 / cfg.bandwidth;
                    busy_per_osd[m.from as usize] += dur;
                    busy_per_osd[m.to as usize] += dur;
                    finish_heap.push(Reverse(Finish { time: now + dur, idx: i }));
                    transfers.push(TransferRecord { movement: *m, start: now, finish: now + dur });
                    made_progress = true;
                }
            }
            pending.retain(|&i| !started[i]);
        }

        // advance to the next completion
        let Some(Reverse(f)) = finish_heap.pop() else { break };
        now = f.time;
        let m = &plan[f.idx];
        inflight_per_osd[m.from as usize] -= 1;
        inflight_per_osd[m.to as usize] -= 1;
        running -= 1;
    }

    let total_bytes = plan.iter().filter(|m| m.from != m.to).map(|m| m.bytes).sum();
    Ok(ExecutionReport {
        transfers,
        makespan: now,
        peak_concurrency: peak,
        total_bytes,
        osd_busy_seconds: busy_per_osd,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::PgId;

    fn mv(pg: u32, from: OsdId, to: OsdId, bytes: u64) -> Movement {
        Movement { pg: PgId::new(1, pg), from, to, bytes }
    }

    #[test]
    fn disjoint_movements_run_concurrently() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 2, 3, 100)];
        let rep = execute_plan(&plan, &cfg, 4).unwrap();
        assert_eq!(rep.peak_concurrency, 2);
        assert!((rep.makespan - 100.0).abs() < 1e-9, "parallel: {}", rep.makespan);
    }

    #[test]
    fn same_osd_movements_serialize() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 0, 2, 100)]; // share source 0
        let rep = execute_plan(&plan, &cfg, 3).unwrap();
        assert_eq!(rep.peak_concurrency, 1);
        assert!((rep.makespan - 200.0).abs() < 1e-9, "serial: {}", rep.makespan);
    }

    #[test]
    fn higher_backfill_limit_raises_concurrency() {
        let cfg = ExecutorConfig { max_backfills: 2, bandwidth: 1.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 0, 2, 100), mv(2, 0, 3, 100)];
        let rep = execute_plan(&plan, &cfg, 4).unwrap();
        assert_eq!(rep.peak_concurrency, 2);
        assert!((rep.makespan - 200.0).abs() < 1e-9);
    }

    #[test]
    fn fifo_within_constraints() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        // plan order: big then small on the same pair; the big one starts first
        let plan = vec![mv(0, 0, 1, 500), mv(1, 0, 1, 10)];
        let rep = execute_plan(&plan, &cfg, 2).unwrap();
        assert!(rep.transfers[0].start < rep.transfers[1].start);
        assert!((rep.makespan - 510.0).abs() < 1e-9);
    }

    #[test]
    fn empty_plan() {
        let rep = execute_plan(&[], &ExecutorConfig::default(), 4).unwrap();
        assert_eq!(rep.makespan, 0.0);
        assert_eq!(rep.total_bytes, 0);
        assert_eq!(rep.peak_concurrency, 0);
    }

    #[test]
    fn throughput_accounts_all_bytes() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 2.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 2, 3, 300)];
        let rep = execute_plan(&plan, &cfg, 4).unwrap();
        assert_eq!(rep.total_bytes, 400);
        assert!((rep.makespan - 150.0).abs() < 1e-9);
        assert!((rep.throughput() - 400.0 / 150.0).abs() < 1e-9);
    }

    #[test]
    fn busy_seconds_account_both_endpoints() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 0, 2, 50)];
        let rep = execute_plan(&plan, &cfg, 3).unwrap();
        assert!((rep.osd_busy_seconds[0] - 150.0).abs() < 1e-9);
        assert!((rep.osd_busy_seconds[1] - 100.0).abs() < 1e-9);
        assert!((rep.osd_busy_seconds[2] - 50.0).abs() < 1e-9);
        let (osd, busy) = rep.bottleneck().unwrap();
        assert_eq!(osd, 0);
        assert!((busy - 150.0).abs() < 1e-9);
        // the bottleneck lane lower-bounds the makespan
        assert!(rep.makespan + 1e-9 >= busy / cfg.max_backfills as f64);
        assert!(execute_plan(&[], &cfg, 3).unwrap().bottleneck().is_none());
    }

    #[test]
    fn bottleneck_handles_nonfinite_busy_seconds() {
        // zero-bandwidth config: every duration is +∞; the pre-fix
        // partial_cmp(..).unwrap() comparator panicked on this report
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 0.0 };
        let rep = execute_plan(&[mv(0, 0, 1, 100)], &cfg, 3).unwrap();
        let (osd, busy) = rep.bottleneck().unwrap();
        assert_eq!(osd, 0, "tie on +inf busy seconds resolves to the lowest id");
        assert!(busy.is_infinite() && busy > 0.0);
        // a hand-built report with a NaN lane must not panic either: the
        // occupancy filter excludes it, total_cmp orders the rest
        let rep = ExecutionReport {
            transfers: vec![],
            makespan: 0.0,
            peak_concurrency: 0,
            total_bytes: 0,
            osd_busy_seconds: vec![f64::NAN, 7.0, 3.0],
        };
        assert_eq!(rep.bottleneck(), Some((1, 7.0)));
    }

    #[test]
    fn bottleneck_tie_breaks_to_lowest_osd_id() {
        let rep = ExecutionReport {
            transfers: vec![],
            makespan: 0.0,
            peak_concurrency: 0,
            total_bytes: 0,
            osd_busy_seconds: vec![0.0, 5.0, 5.0, 2.0],
        };
        assert_eq!(rep.bottleneck(), Some((1, 5.0)), "equal busy seconds → lowest OSD id");
    }

    #[test]
    fn self_move_is_skipped_not_double_counted() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        // pre-fix, the self-move booked both inflight slots and 2×100s of
        // busy time on OSD 0 and serialized the real transfer behind it
        let plan = vec![mv(0, 0, 0, 100), mv(1, 0, 1, 50)];
        let rep = execute_plan(&plan, &cfg, 2).unwrap();
        assert_eq!(rep.transfers.len(), 1, "self-move produces no transfer");
        assert_eq!(rep.transfers[0].movement.pg.index, 1);
        assert_eq!(rep.transfers[0].start, 0.0, "self-move holds no backfill slot");
        assert_eq!(rep.total_bytes, 50, "self-move bytes transfer nothing");
        assert!((rep.osd_busy_seconds[0] - 50.0).abs() < 1e-9);
        // a plan of only self-moves is a no-op
        let rep = execute_plan(&[mv(0, 3, 3, 10)], &cfg, 4).unwrap();
        assert_eq!(rep.makespan, 0.0);
        assert_eq!(rep.total_bytes, 0);
        assert!(rep.bottleneck().is_none());
    }

    #[test]
    fn out_of_range_osd_is_a_typed_error() {
        let cfg = ExecutorConfig::default();
        let err = execute_plan(&[mv(0, 0, 1, 10), mv(1, 2, 9, 10)], &cfg, 4).unwrap_err();
        assert_eq!(err, ExecutorError::OsdOutOfRange { index: 1, osd: 9, osd_count: 4 });
        assert!(err.to_string().contains("osd.9"));
        let err = execute_plan(&[mv(0, 9, 1, 10)], &cfg, 4).unwrap_err();
        assert_eq!(err, ExecutorError::OsdOutOfRange { index: 0, osd: 9, osd_count: 4 });
    }

    #[test]
    fn blocked_head_does_not_starve_rest() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        // move 1 blocks on OSD 0 (busy with move 0); move 2 is disjoint
        // and must start immediately despite being later in the plan
        let plan = vec![mv(0, 0, 1, 1000), mv(1, 0, 2, 10), mv(2, 3, 4, 10)];
        let rep = execute_plan(&plan, &cfg, 5).unwrap();
        let t2 = rep.transfers.iter().find(|t| t.movement.pg.index == 2).unwrap();
        assert_eq!(t2.start, 0.0, "disjoint move must not wait behind a blocked head");
    }
}
