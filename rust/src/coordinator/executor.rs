//! Movement executor: discrete-event simulation of actually *carrying
//! out* a movement plan on a cluster, with Ceph-style backfill
//! throttling.
//!
//! The balancers answer "which shards should move"; this component
//! answers "how long will the data movement take and how do we keep it
//! from starving client I/O". It models Ceph's `osd_max_backfills` (at
//! most `max_backfills` concurrent transfers touching any one OSD, as
//! source or destination) and a per-transfer recovery bandwidth. The
//! paper argues the planning-time investment is negligible because
//! "storage movements of several terabytes require more time than
//! planning" — this executor quantifies that claim (EXPERIMENTS.md).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::Movement;
use crate::crush::OsdId;

/// Executor tunables.
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Max concurrent transfers per OSD (Ceph default osd_max_backfills=1).
    pub max_backfills: usize,
    /// Per-transfer throughput, bytes/second (HDD-ish default 100 MiB/s).
    pub bandwidth: f64,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig { max_backfills: 1, bandwidth: 100.0 * (1 << 20) as f64 }
    }
}

/// Completed-transfer record.
#[derive(Debug, Clone)]
pub struct TransferRecord {
    pub movement: Movement,
    /// Virtual start time, seconds.
    pub start: f64,
    /// Virtual completion time, seconds.
    pub finish: f64,
}

/// Result of executing a plan.
#[derive(Debug, Clone)]
pub struct ExecutionReport {
    pub transfers: Vec<TransferRecord>,
    /// Virtual wall-clock of the whole plan, seconds.
    pub makespan: f64,
    /// Peak number of simultaneous transfers.
    pub peak_concurrency: usize,
    pub total_bytes: u64,
    /// Per-OSD transfer-lane occupancy, seconds: every transfer adds its
    /// duration to both endpoints. Shows which devices bound a batch —
    /// the makespan is at least `max(osd_busy_seconds) / max_backfills`.
    pub osd_busy_seconds: Vec<f64>,
}

impl ExecutionReport {
    /// Aggregate achieved throughput, bytes/second.
    pub fn throughput(&self) -> f64 {
        if self.makespan > 0.0 {
            self.total_bytes as f64 / self.makespan
        } else {
            0.0
        }
    }

    /// The OSD whose transfer lanes were occupied longest (the batch's
    /// bottleneck device), with its busy seconds. None for empty plans.
    pub fn bottleneck(&self) -> Option<(OsdId, f64)> {
        self.osd_busy_seconds
            .iter()
            .enumerate()
            .filter(|&(_, &b)| b > 0.0)
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .map(|(o, &b)| (o as OsdId, b))
    }
}

#[derive(Debug, PartialEq)]
struct Finish {
    time: f64,
    idx: usize,
}

impl Eq for Finish {}

impl PartialOrd for Finish {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Finish {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .unwrap()
            .then(self.idx.cmp(&other.idx))
    }
}

/// Execute `plan` (in order, FIFO per constraint) under the config's
/// concurrency limits. Movements are started greedily: at every event
/// time the earliest-planned movement whose source and destination both
/// have a free backfill slot starts.
pub fn execute_plan(plan: &[Movement], cfg: &ExecutorConfig, osd_count: usize) -> ExecutionReport {
    let mut inflight_per_osd: Vec<usize> = vec![0; osd_count];
    let mut busy_per_osd: Vec<f64> = vec![0.0; osd_count];
    let mut pending: Vec<usize> = (0..plan.len()).collect(); // indices, plan order
    let mut finish_heap: BinaryHeap<Reverse<Finish>> = BinaryHeap::new();
    let mut transfers: Vec<TransferRecord> = Vec::with_capacity(plan.len());
    let mut now = 0.0f64;
    let mut running = 0usize;
    let mut peak = 0usize;
    let mut started = vec![false; plan.len()];

    let slot_free = |inflight: &[usize], osd: OsdId, cfg: &ExecutorConfig| {
        inflight[osd as usize] < cfg.max_backfills
    };

    loop {
        // start everything startable at `now`, in plan order
        let mut made_progress = true;
        while made_progress {
            made_progress = false;
            for &i in &pending {
                if started[i] {
                    continue;
                }
                let m = &plan[i];
                if slot_free(&inflight_per_osd, m.from, cfg)
                    && slot_free(&inflight_per_osd, m.to, cfg)
                {
                    started[i] = true;
                    inflight_per_osd[m.from as usize] += 1;
                    inflight_per_osd[m.to as usize] += 1;
                    running += 1;
                    peak = peak.max(running);
                    let dur = m.bytes as f64 / cfg.bandwidth;
                    busy_per_osd[m.from as usize] += dur;
                    busy_per_osd[m.to as usize] += dur;
                    finish_heap.push(Reverse(Finish { time: now + dur, idx: i }));
                    transfers.push(TransferRecord { movement: *m, start: now, finish: now + dur });
                    made_progress = true;
                }
            }
            pending.retain(|&i| !started[i]);
        }

        // advance to the next completion
        let Some(Reverse(f)) = finish_heap.pop() else { break };
        now = f.time;
        let m = &plan[f.idx];
        inflight_per_osd[m.from as usize] -= 1;
        inflight_per_osd[m.to as usize] -= 1;
        running -= 1;
    }

    let total_bytes = plan.iter().map(|m| m.bytes).sum();
    ExecutionReport {
        transfers,
        makespan: now,
        peak_concurrency: peak,
        total_bytes,
        osd_busy_seconds: busy_per_osd,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::PgId;

    fn mv(pg: u32, from: OsdId, to: OsdId, bytes: u64) -> Movement {
        Movement { pg: PgId::new(1, pg), from, to, bytes }
    }

    #[test]
    fn disjoint_movements_run_concurrently() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 2, 3, 100)];
        let rep = execute_plan(&plan, &cfg, 4);
        assert_eq!(rep.peak_concurrency, 2);
        assert!((rep.makespan - 100.0).abs() < 1e-9, "parallel: {}", rep.makespan);
    }

    #[test]
    fn same_osd_movements_serialize() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 0, 2, 100)]; // share source 0
        let rep = execute_plan(&plan, &cfg, 3);
        assert_eq!(rep.peak_concurrency, 1);
        assert!((rep.makespan - 200.0).abs() < 1e-9, "serial: {}", rep.makespan);
    }

    #[test]
    fn higher_backfill_limit_raises_concurrency() {
        let cfg = ExecutorConfig { max_backfills: 2, bandwidth: 1.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 0, 2, 100), mv(2, 0, 3, 100)];
        let rep = execute_plan(&plan, &cfg, 4);
        assert_eq!(rep.peak_concurrency, 2);
        assert!((rep.makespan - 200.0).abs() < 1e-9);
    }

    #[test]
    fn fifo_within_constraints() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        // plan order: big then small on the same pair; the big one starts first
        let plan = vec![mv(0, 0, 1, 500), mv(1, 0, 1, 10)];
        let rep = execute_plan(&plan, &cfg, 2);
        assert!(rep.transfers[0].start < rep.transfers[1].start);
        assert!((rep.makespan - 510.0).abs() < 1e-9);
    }

    #[test]
    fn empty_plan() {
        let rep = execute_plan(&[], &ExecutorConfig::default(), 4);
        assert_eq!(rep.makespan, 0.0);
        assert_eq!(rep.total_bytes, 0);
        assert_eq!(rep.peak_concurrency, 0);
    }

    #[test]
    fn throughput_accounts_all_bytes() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 2.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 2, 3, 300)];
        let rep = execute_plan(&plan, &cfg, 4);
        assert_eq!(rep.total_bytes, 400);
        assert!((rep.makespan - 150.0).abs() < 1e-9);
        assert!((rep.throughput() - 400.0 / 150.0).abs() < 1e-9);
    }

    #[test]
    fn busy_seconds_account_both_endpoints() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        let plan = vec![mv(0, 0, 1, 100), mv(1, 0, 2, 50)];
        let rep = execute_plan(&plan, &cfg, 3);
        assert!((rep.osd_busy_seconds[0] - 150.0).abs() < 1e-9);
        assert!((rep.osd_busy_seconds[1] - 100.0).abs() < 1e-9);
        assert!((rep.osd_busy_seconds[2] - 50.0).abs() < 1e-9);
        let (osd, busy) = rep.bottleneck().unwrap();
        assert_eq!(osd, 0);
        assert!((busy - 150.0).abs() < 1e-9);
        // the bottleneck lane lower-bounds the makespan
        assert!(rep.makespan + 1e-9 >= busy / cfg.max_backfills as f64);
        assert!(execute_plan(&[], &cfg, 3).bottleneck().is_none());
    }

    #[test]
    fn blocked_head_does_not_starve_rest() {
        let cfg = ExecutorConfig { max_backfills: 1, bandwidth: 1.0 };
        // move 1 blocks on OSD 0 (busy with move 0); move 2 is disjoint
        // and must start immediately despite being later in the plan
        let plan = vec![mv(0, 0, 1, 1000), mv(1, 0, 2, 10), mv(2, 3, 4, 10)];
        let rep = execute_plan(&plan, &cfg, 5);
        let t2 = rep.transfers.iter().find(|t| t.movement.pg.index == 2).unwrap();
        assert_eq!(t2.start, 0.0, "disjoint move must not wait behind a blocked head");
    }
}
