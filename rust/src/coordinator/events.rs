//! Structured event log for the balancing daemon.

use crate::util::units::{fmt_bytes, fmt_duration};

/// One coordinator event, stamped with virtual time.
#[derive(Debug, Clone)]
pub enum Event {
    RoundStarted { round: usize },
    WritesApplied { round: usize, user_bytes: u64 },
    PlanComputed { round: usize, moves: usize, bytes: u64, calc_seconds: f64 },
    PlanExecuted { round: usize, makespan: f64, peak_concurrency: usize },
    Converged { round: usize },
}

/// Append-only event log.
#[derive(Debug, Default)]
pub struct EventLog {
    events: Vec<(f64, Event)>,
}

impl EventLog {
    pub fn push(&mut self, vtime: f64, event: Event) {
        self.events.push((vtime, event));
    }

    pub fn events(&self) -> &[(f64, Event)] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Human-readable rendering, one event per line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (t, e) in &self.events {
            let line = match e {
                Event::RoundStarted { round } => format!("round {round} started"),
                Event::WritesApplied { round, user_bytes } => {
                    format!("round {round}: clients wrote {}", fmt_bytes(*user_bytes))
                }
                Event::PlanComputed { round, moves, bytes, calc_seconds } => format!(
                    "round {round}: planned {moves} moves ({}) in {}",
                    fmt_bytes(*bytes),
                    fmt_duration(*calc_seconds)
                ),
                Event::PlanExecuted { round, makespan, peak_concurrency } => format!(
                    "round {round}: plan executed in {} (peak {} concurrent backfills)",
                    fmt_duration(*makespan),
                    peak_concurrency
                ),
                Event::Converged { round } => format!("round {round}: balancer converged"),
            };
            out.push_str(&format!("[t={:>10}] {}\n", fmt_duration(*t), line));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_renders_all_events() {
        let mut log = EventLog::default();
        log.push(0.0, Event::RoundStarted { round: 1 });
        log.push(1.0, Event::WritesApplied { round: 1, user_bytes: 1 << 30 });
        log.push(
            2.0,
            Event::PlanComputed { round: 1, moves: 5, bytes: 5 << 30, calc_seconds: 0.01 },
        );
        log.push(60.0, Event::PlanExecuted { round: 1, makespan: 58.0, peak_concurrency: 3 });
        log.push(61.0, Event::Converged { round: 1 });
        let text = log.render();
        assert_eq!(text.lines().count(), 5);
        assert!(text.contains("planned 5 moves"));
        assert!(text.contains("converged"));
        assert_eq!(log.len(), 5);
        assert!(!log.is_empty());
    }
}
