//! Structured event log for the balancing daemon and the scenario
//! engine: every operational occurrence — client writes, plans,
//! throttled executions, failures, recoveries, expansions, pool
//! lifecycle — stamped with virtual time.

use crate::crush::OsdId;
use crate::util::units::{fmt_bytes, fmt_duration};

/// One coordinator/scenario event, stamped with virtual time.
#[derive(Debug, Clone)]
pub enum Event {
    RoundStarted { round: usize },
    WritesApplied { round: usize, user_bytes: u64 },
    PlanComputed { round: usize, moves: usize, bytes: u64, calc_seconds: f64 },
    /// The plan pipeline (RFC 0003) rewrote a round's raw plan into its
    /// minimal equivalent before execution.
    PlanOptimized { round: usize, raw_moves: usize, moves: usize, raw_bytes: u64, bytes: u64 },
    /// One concurrency-capped phase of a scheduled plan was executed.
    PhaseExecuted { round: usize, phase: usize, moves: usize, makespan: f64 },
    PlanExecuted { round: usize, makespan: f64, peak_concurrency: usize },
    Converged { round: usize },
    /// A device failed; its shards were re-placed (`backfills` of them,
    /// `bytes` total) or left degraded.
    OsdFailed { osd: OsdId, backfills: usize, bytes: u64, degraded: usize },
    /// A whole host failed (`osds` devices down).
    HostFailed { host: String, osds: usize, backfills: usize, bytes: u64, degraded: usize },
    /// Backfill/recovery traffic was executed under throttling.
    RecoveryExecuted { makespan: f64, bytes: u64 },
    /// New empty hosts were attached to the hierarchy.
    HostsAdded { hosts: usize, osds: usize, bytes_per_osd: u64 },
    /// A pool was created on the live cluster.
    PoolCreated { pool: u32, pgs: u32, user_bytes: u64 },
    /// Targeted writes grew one pool.
    PoolGrown { pool: u32, user_bytes: u64 },
    /// Object deletions shrank one pool.
    PoolShrunk { pool: u32, user_bytes: u64 },
    /// A pool was decommissioned: all of its data deleted.
    PoolDrained { pool: u32, bytes: u64 },
    /// The cluster was aged through grow/shrink epochs.
    Aged { epochs: usize },
    /// A labelled measurement snapshot was captured.
    SnapshotTaken { label: String },
}

/// Append-only event log.
#[derive(Debug, Default)]
pub struct EventLog {
    events: Vec<(f64, Event)>,
}

impl EventLog {
    pub fn push(&mut self, vtime: f64, event: Event) {
        self.events.push((vtime, event));
    }

    pub fn events(&self) -> &[(f64, Event)] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Human-readable rendering, one event per line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (t, e) in &self.events {
            let line = match e {
                Event::RoundStarted { round } => format!("round {round} started"),
                Event::WritesApplied { round, user_bytes } => {
                    format!("round {round}: clients wrote {}", fmt_bytes(*user_bytes))
                }
                Event::PlanComputed { round, moves, bytes, calc_seconds } => format!(
                    "round {round}: planned {moves} moves ({}) in {}",
                    fmt_bytes(*bytes),
                    fmt_duration(*calc_seconds)
                ),
                Event::PlanOptimized { round, raw_moves, moves, raw_bytes, bytes } => format!(
                    "round {round}: plan optimized {raw_moves} -> {moves} moves ({} -> {})",
                    fmt_bytes(*raw_bytes),
                    fmt_bytes(*bytes)
                ),
                Event::PhaseExecuted { round, phase, moves, makespan } => format!(
                    "round {round}: phase {} executed {moves} moves in {}",
                    phase + 1,
                    fmt_duration(*makespan)
                ),
                Event::PlanExecuted { round, makespan, peak_concurrency } => format!(
                    "round {round}: plan executed in {} (peak {} concurrent backfills)",
                    fmt_duration(*makespan),
                    peak_concurrency
                ),
                Event::Converged { round } => format!("round {round}: balancer converged"),
                Event::OsdFailed { osd, backfills, bytes, degraded } => format!(
                    "osd.{osd} failed: {backfills} backfills ({}){}",
                    fmt_bytes(*bytes),
                    if *degraded > 0 { format!(", {degraded} degraded PGs") } else { String::new() }
                ),
                Event::HostFailed { host, osds, backfills, bytes, degraded } => format!(
                    "host {host} failed ({osds} OSDs): {backfills} backfills ({}){}",
                    fmt_bytes(*bytes),
                    if *degraded > 0 { format!(", {degraded} degraded PGs") } else { String::new() }
                ),
                Event::RecoveryExecuted { makespan, bytes } => format!(
                    "recovery executed: {} in {}",
                    fmt_bytes(*bytes),
                    fmt_duration(*makespan)
                ),
                Event::HostsAdded { hosts, osds, bytes_per_osd } => format!(
                    "expansion: {hosts} hosts / {osds} OSDs of {} added",
                    fmt_bytes(*bytes_per_osd)
                ),
                Event::PoolCreated { pool, pgs, user_bytes } => format!(
                    "pool {pool} created ({pgs} PGs, {})",
                    fmt_bytes(*user_bytes)
                ),
                Event::PoolGrown { pool, user_bytes } => {
                    format!("pool {pool} grew by {}", fmt_bytes(*user_bytes))
                }
                Event::PoolShrunk { pool, user_bytes } => {
                    format!("pool {pool} shrank by {}", fmt_bytes(*user_bytes))
                }
                Event::PoolDrained { pool, bytes } => {
                    format!("pool {pool} decommissioned ({} deleted)", fmt_bytes(*bytes))
                }
                Event::Aged { epochs } => format!("cluster aged {epochs} epochs"),
                Event::SnapshotTaken { label } => format!("snapshot '{label}'"),
            };
            out.push_str(&format!("[t={:>10}] {}\n", fmt_duration(*t), line));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_renders_all_events() {
        let mut log = EventLog::default();
        log.push(0.0, Event::RoundStarted { round: 1 });
        log.push(1.0, Event::WritesApplied { round: 1, user_bytes: 1 << 30 });
        log.push(
            2.0,
            Event::PlanComputed { round: 1, moves: 5, bytes: 5 << 30, calc_seconds: 0.01 },
        );
        log.push(60.0, Event::PlanExecuted { round: 1, makespan: 58.0, peak_concurrency: 3 });
        log.push(61.0, Event::Converged { round: 1 });
        log.push(
            62.0,
            Event::PlanOptimized { round: 2, raw_moves: 9, moves: 6, raw_bytes: 9 << 30, bytes: 6 << 30 },
        );
        log.push(63.0, Event::PhaseExecuted { round: 2, phase: 0, moves: 3, makespan: 30.0 });
        let text = log.render();
        assert_eq!(text.lines().count(), 7);
        assert!(text.contains("planned 5 moves"));
        assert!(text.contains("converged"));
        assert!(text.contains("plan optimized 9 -> 6 moves"));
        assert!(text.contains("phase 1 executed 3 moves"));
        assert_eq!(log.len(), 7);
        assert!(!log.is_empty());
    }

    #[test]
    fn scenario_events_render() {
        let mut log = EventLog::default();
        log.push(0.0, Event::OsdFailed { osd: 3, backfills: 7, bytes: 1 << 30, degraded: 1 });
        log.push(1.0, Event::HostFailed { host: "host001".into(), osds: 2, backfills: 9, bytes: 2 << 30, degraded: 0 });
        log.push(2.0, Event::RecoveryExecuted { makespan: 12.5, bytes: 3 << 30 });
        log.push(3.0, Event::HostsAdded { hosts: 2, osds: 8, bytes_per_osd: 4 << 40 });
        log.push(4.0, Event::PoolCreated { pool: 9, pgs: 32, user_bytes: 1 << 40 });
        log.push(5.0, Event::PoolGrown { pool: 9, user_bytes: 1 << 30 });
        log.push(6.0, Event::PoolShrunk { pool: 9, user_bytes: 1 << 29 });
        log.push(7.0, Event::PoolDrained { pool: 9, bytes: 1 << 40 });
        log.push(8.0, Event::Aged { epochs: 12 });
        log.push(9.0, Event::SnapshotTaken { label: "steady".into() });
        let text = log.render();
        assert_eq!(text.lines().count(), 10);
        assert!(text.contains("osd.3 failed"));
        assert!(text.contains("1 degraded"));
        assert!(text.contains("host host001 failed"));
        assert!(text.contains("expansion: 2 hosts"));
        assert!(text.contains("pool 9 created"));
        assert!(text.contains("decommissioned"));
        assert!(text.contains("snapshot 'steady'"));
    }
}
