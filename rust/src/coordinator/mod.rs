//! Layer-3 coordinator: the operational side of balancing — executing
//! movement plans under backfill throttling (discrete-event executor)
//! and the daemon loop that interleaves client writes, planning, and
//! execution with backpressure.

pub mod daemon;
pub mod events;
pub mod executor;
pub mod throttle;

pub use daemon::{run_daemon, DaemonConfig, DaemonReport, RoundReport};
pub use events::{Event, EventLog};
pub use executor::{execute_plan, ExecutionReport, ExecutorConfig, ExecutorError, TransferRecord};
pub use throttle::Throttle;
