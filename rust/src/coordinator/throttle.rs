//! Adaptive backpressure for the daemon: size each round's movement
//! budget so plan execution fits the round's time budget.
//!
//! The executor tells us how long the last batch took; an AIMD
//! (additive-increase / multiplicative-decrease) controller adjusts the
//! next batch size. This keeps recovery I/O bounded — the operational
//! concern that makes operators afraid of balancers in the first place.

/// AIMD controller over the per-round movement budget.
#[derive(Debug, Clone)]
pub struct Throttle {
    /// Current budget (moves per round).
    budget: usize,
    pub min_budget: usize,
    pub max_budget: usize,
    /// Target execution time per round, seconds.
    pub target_seconds: f64,
    /// Additive increase step when under target.
    pub increase: usize,
    /// Multiplicative decrease factor when over target.
    pub decrease: f64,
}

/// Fallback round target when the caller hands `Throttle::new` a
/// non-finite or non-positive `target_seconds`.
pub const DEFAULT_TARGET_SECONDS: f64 = 60.0;

/// Fallback multiplicative-decrease factor when the public `decrease`
/// field is set outside the meaningful open interval `(0, 1)`.
pub const DEFAULT_DECREASE: f64 = 0.5;

impl Throttle {
    /// Build a controller with `initial` moves per round aiming at
    /// `target_seconds` per round. A non-finite or non-positive target
    /// would make the over-target comparison in [`Throttle::observe`]
    /// vacuous (never or always true), so such inputs are replaced with
    /// [`DEFAULT_TARGET_SECONDS`].
    pub fn new(initial: usize, target_seconds: f64) -> Throttle {
        let target_seconds = if target_seconds.is_finite() && target_seconds > 0.0 {
            target_seconds
        } else {
            DEFAULT_TARGET_SECONDS
        };
        Throttle {
            budget: initial.max(1),
            min_budget: 1,
            max_budget: 10_000,
            target_seconds,
            increase: 5,
            decrease: 0.5,
        }
    }

    /// Current budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// The `decrease` field is public; a value ≥ 1.0 (or ≤ 0, or NaN)
    /// would turn multiplicative decrease into a no-op or an *increase*,
    /// so anything outside `(0, 1)` falls back to [`DEFAULT_DECREASE`].
    fn effective_decrease(&self) -> f64 {
        if self.decrease > 0.0 && self.decrease < 1.0 {
            self.decrease
        } else {
            DEFAULT_DECREASE
        }
    }

    /// Feed back the measured makespan of the executed round; returns the
    /// next round's budget. A non-finite makespan (NaN or ±∞ from a
    /// degenerate executor config) is treated as "over target": the
    /// budget backs off by the multiplicative-decrease factor rather
    /// than sneaking through the additive-increase branch.
    pub fn observe(&mut self, makespan_seconds: f64, moves_executed: usize) -> usize {
        if moves_executed == 0 {
            // nothing ran (converged or blocked) — keep the budget
            return self.budget;
        }
        let decrease = self.effective_decrease();
        if !makespan_seconds.is_finite() {
            self.budget =
                ((self.budget as f64 * decrease).floor() as usize).max(self.min_budget).max(1);
        } else if makespan_seconds > self.target_seconds {
            // too slow: back off proportionally to the overshoot, at
            // least the multiplicative decrease
            let factor = (self.target_seconds / makespan_seconds).min(decrease);
            self.budget =
                ((self.budget as f64 * factor).floor() as usize).max(self.min_budget).max(1);
        } else {
            self.budget = (self.budget + self.increase).min(self.max_budget);
        }
        self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increases_when_fast() {
        let mut t = Throttle::new(10, 60.0);
        let b = t.observe(10.0, 10);
        assert_eq!(b, 15);
        assert_eq!(t.observe(10.0, 15), 20);
    }

    #[test]
    fn backs_off_when_slow() {
        let mut t = Throttle::new(100, 60.0);
        let b = t.observe(240.0, 100); // 4x over target → quarter
        assert_eq!(b, 25);
    }

    #[test]
    fn respects_bounds() {
        let mut t = Throttle::new(2, 60.0);
        t.min_budget = 2;
        assert_eq!(t.observe(1e9, 2), 2, "never below min");
        let mut t2 = Throttle::new(9998, 60.0);
        t2.max_budget = 10_000;
        assert_eq!(t2.observe(1.0, 9998), 10_000);
        assert_eq!(t2.observe(1.0, 10_000), 10_000, "capped at max");
    }

    #[test]
    fn zero_moves_keeps_budget() {
        let mut t = Throttle::new(50, 60.0);
        assert_eq!(t.observe(0.0, 0), 50);
    }

    #[test]
    fn constructor_sanitizes_degenerate_targets() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -5.0] {
            let t = Throttle::new(10, bad);
            assert_eq!(
                t.target_seconds, DEFAULT_TARGET_SECONDS,
                "target {bad} must fall back to the default"
            );
        }
        assert_eq!(Throttle::new(10, 90.0).target_seconds, 90.0);
    }

    #[test]
    fn nonfinite_makespan_is_over_target() {
        // pre-fix, NaN > target was false and the budget *increased*
        let mut t = Throttle::new(100, 60.0);
        assert_eq!(t.observe(f64::NAN, 100), 50, "NaN makespan must back off");
        let mut t = Throttle::new(100, 60.0);
        assert_eq!(t.observe(f64::INFINITY, 100), 50, "inf makespan must back off");
    }

    #[test]
    fn misconfigured_decrease_falls_back() {
        // a *slight* overshoot (61s vs 60s target) must still back off by
        // at least the multiplicative factor; pre-fix a decrease outside
        // (0, 1) let factor = min(60/61, decrease) degrade to ≈1 (no-op)
        // or to 0 (collapse to min_budget) instead
        for bad in [1.0, 1.5, 0.0, -0.5, f64::NAN] {
            let mut t = Throttle::new(100, 60.0);
            t.decrease = bad;
            assert_eq!(t.observe(61.0, 100), 50, "decrease {bad} must fall back to 0.5");
        }
        // a valid decrease is still honored
        let mut t = Throttle::new(100, 60.0);
        t.decrease = 0.25;
        assert_eq!(t.observe(61.0, 100), 25);
    }
}
