//! Adaptive backpressure for the daemon: size each round's movement
//! budget so plan execution fits the round's time budget.
//!
//! The executor tells us how long the last batch took; an AIMD
//! (additive-increase / multiplicative-decrease) controller adjusts the
//! next batch size. This keeps recovery I/O bounded — the operational
//! concern that makes operators afraid of balancers in the first place.

/// AIMD controller over the per-round movement budget.
#[derive(Debug, Clone)]
pub struct Throttle {
    /// Current budget (moves per round).
    budget: usize,
    pub min_budget: usize,
    pub max_budget: usize,
    /// Target execution time per round, seconds.
    pub target_seconds: f64,
    /// Additive increase step when under target.
    pub increase: usize,
    /// Multiplicative decrease factor when over target.
    pub decrease: f64,
}

impl Throttle {
    pub fn new(initial: usize, target_seconds: f64) -> Throttle {
        Throttle {
            budget: initial.max(1),
            min_budget: 1,
            max_budget: 10_000,
            target_seconds,
            increase: 5,
            decrease: 0.5,
        }
    }

    /// Current budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Feed back the measured makespan of the executed round; returns the
    /// next round's budget.
    pub fn observe(&mut self, makespan_seconds: f64, moves_executed: usize) -> usize {
        if moves_executed == 0 {
            // nothing ran (converged or blocked) — keep the budget
            return self.budget;
        }
        if makespan_seconds > self.target_seconds {
            // too slow: back off proportionally to the overshoot, at
            // least the multiplicative decrease
            let factor = (self.target_seconds / makespan_seconds).min(self.decrease);
            self.budget = ((self.budget as f64 * factor).floor() as usize).max(self.min_budget);
        } else {
            self.budget = (self.budget + self.increase).min(self.max_budget);
        }
        self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increases_when_fast() {
        let mut t = Throttle::new(10, 60.0);
        let b = t.observe(10.0, 10);
        assert_eq!(b, 15);
        assert_eq!(t.observe(10.0, 15), 20);
    }

    #[test]
    fn backs_off_when_slow() {
        let mut t = Throttle::new(100, 60.0);
        let b = t.observe(240.0, 100); // 4x over target → quarter
        assert_eq!(b, 25);
    }

    #[test]
    fn respects_bounds() {
        let mut t = Throttle::new(2, 60.0);
        t.min_budget = 2;
        assert_eq!(t.observe(1e9, 2), 2, "never below min");
        let mut t2 = Throttle::new(9998, 60.0);
        t2.max_budget = 10_000;
        assert_eq!(t2.observe(1.0, 9998), 10_000);
        assert_eq!(t2.observe(1.0, 10_000), 10_000, "capped at max");
    }

    #[test]
    fn zero_moves_keeps_budget() {
        let mut t = Throttle::new(50, 60.0);
        assert_eq!(t.observe(0.0, 0), 50);
    }
}
