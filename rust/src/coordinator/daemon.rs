//! The balancing daemon: the operational loop a cluster operator runs.
//!
//! Interleaves (in virtual time) three activities the paper treats
//! separately: clients writing new data (which re-skews the cluster),
//! the balancer planning movements, and the executor carrying the
//! movements out under backfill throttling. This is the "streaming
//! orchestrator with backpressure" role of the Layer-3 coordinator: a
//! round only plans as many movements as the executor can absorb, so
//! balancing never overwhelms recovery I/O.
//!
//! Since the scenario-engine refactor the loop is a thin adapter: each
//! round is a `WorkloadPhase` + `BalanceRound` pair executed by
//! [`crate::scenario::ScenarioEngine`], which owns the virtual clock,
//! the executor, and the AIMD throttle.

use crate::balancer::Balancer;
use crate::cluster::ClusterState;
use crate::plan::{PlanConfig, PlanReport};
use crate::scenario::{ScenarioConfig, ScenarioEngine, ScenarioEvent};
use crate::simulator::workload::WorkloadModel;

use super::events::{Event, EventLog};
use super::executor::ExecutorConfig;

/// Daemon tunables.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Number of write→plan→execute rounds.
    pub rounds: usize,
    /// Movement budget per round (backpressure: don't plan more than the
    /// executor can run in a round).
    pub moves_per_round: usize,
    /// User bytes written by clients per round (spread over data pools).
    pub write_bytes_per_round: u64,
    /// How client writes distribute over pools.
    pub workload: WorkloadModel,
    /// When set, the per-round movement budget adapts (AIMD) so each
    /// round's execution fits this many (virtual) seconds.
    pub target_round_seconds: Option<f64>,
    /// Executor limits.
    pub executor: ExecutorConfig,
    /// Movement plan pipeline (RFC 0003): optimize each round's plan
    /// and/or execute it in concurrency-capped phases. Off by default.
    pub plan: PlanConfig,
    /// Workload seed.
    pub seed: u64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            rounds: 10,
            moves_per_round: 50,
            write_bytes_per_round: 0,
            workload: WorkloadModel::Uniform,
            target_round_seconds: None,
            executor: ExecutorConfig::default(),
            plan: PlanConfig::default(),
            seed: 0xDAE_0001,
        }
    }
}

/// Per-round summary.
#[derive(Debug, Clone)]
pub struct RoundReport {
    pub round: usize,
    pub written_user_bytes: u64,
    pub planned_moves: usize,
    pub moved_bytes: u64,
    /// Bytes physically executed — less than `moved_bytes` when the
    /// plan pipeline cancelled redundant movement.
    pub executed_bytes: u64,
    /// Phases the round executed in (1 without a scheduler).
    pub phases: usize,
    /// Executor makespan of this round's plan, seconds (virtual).
    pub makespan: f64,
    pub variance_after: f64,
    pub total_avail_after: f64,
    pub converged: bool,
}

/// Daemon output: per-round reports plus the full event log.
#[derive(Debug)]
pub struct DaemonReport {
    pub rounds: Vec<RoundReport>,
    pub log: EventLog,
    /// Aggregated plan-pipeline effect (zeros when disabled).
    pub plan: PlanReport,
    /// Total virtual time elapsed, seconds.
    pub elapsed: f64,
}

/// Run the daemon loop: each round is a `WorkloadPhase` (client writes
/// re-skew the cluster) followed by a `BalanceRound` (a bounded
/// `propose_batch` plan executed under backfill limits, with adaptive
/// AIMD backpressure when `target_round_seconds` is set). The scenario
/// engine owns virtual time end to end.
///
/// Note on reproducibility: runs are deterministic per `cfg.seed`, but
/// the write streams differ from the pre-refactor daemon for the same
/// seed — each round's `WorkloadPhase` draws a fresh workload RNG from
/// the engine's seed stream, where the old loop carried one workload
/// RNG across rounds. Round 0 matches; later rounds diverge.
pub fn run_daemon(
    state: &mut ClusterState,
    balancer: &mut dyn Balancer,
    cfg: &DaemonConfig,
) -> DaemonReport {
    let mut engine = ScenarioEngine::new(
        state,
        Some(balancer),
        ScenarioConfig {
            executor: Some(cfg.executor.clone()),
            target_round_seconds: cfg.target_round_seconds,
            // the daemon reports per round, not per move, and discards
            // the time series — skip sample capture entirely
            sample_every: usize::MAX,
            record_series: false,
            plan: cfg.plan.clone(),
            snapshot_dir: None,
        },
        cfg.seed,
    );
    let mut rounds = Vec::new();

    for round in 0..cfg.rounds {
        engine.log_event(Event::RoundStarted { round });
        let writes = engine
            .apply(&ScenarioEvent::WorkloadPhase {
                model: cfg.workload.clone(),
                user_bytes: cfg.write_bytes_per_round,
                duration: 0.0,
            })
            .expect("workload phases cannot fail");
        let plan = engine
            .apply(&ScenarioEvent::BalanceRound { max_moves: cfg.moves_per_round })
            .expect("a balancer is attached, so BalanceRound cannot fail");

        rounds.push(RoundReport {
            round,
            written_user_bytes: writes.written_bytes,
            planned_moves: plan.planned_moves,
            moved_bytes: plan.moved_bytes,
            executed_bytes: plan.executed_bytes,
            phases: plan.phases,
            makespan: plan.makespan,
            variance_after: engine.state().utilization_variance(),
            total_avail_after: engine.state().total_max_avail(true),
            converged: plan.converged,
        });

        if plan.converged && cfg.write_bytes_per_round == 0 {
            break; // nothing will change anymore
        }
    }

    let out = engine.finish();
    DaemonReport { rounds, log: out.log, plan: out.plan, elapsed: out.elapsed }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::Equilibrium;
    use crate::cluster::{ClusterState, Pool};
    use crate::crush::{CrushBuilder, DeviceClass, Level, Rule};
    use crate::util::units::{GIB, TIB};

    fn cluster() -> ClusterState {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..6 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            let size = if h % 2 == 0 { 8 * TIB } else { 4 * TIB };
            b.add_osd_bytes(host, size, DeviceClass::Hdd);
        }
        b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
        ClusterState::build(
            b.build().unwrap(),
            vec![Pool::replicated(1, "p", 3, 64, 0)],
            |_, i| (10 + (i % 7) as u64) * GIB,
        )
    }

    #[test]
    fn daemon_without_writes_converges_and_stops() {
        let mut s = cluster();
        let mut bal = Equilibrium::default();
        let report = run_daemon(&mut s, &mut bal, &DaemonConfig::default());
        assert!(report.rounds.iter().any(|r| r.converged));
        let last = report.rounds.last().unwrap();
        let first = report.rounds.first().unwrap();
        assert!(last.variance_after <= first.variance_after);
        assert!(!report.log.is_empty());
        assert!(s.verify().is_empty());
    }

    #[test]
    fn daemon_with_writes_keeps_balancing() {
        let mut s = cluster();
        let mut bal = Equilibrium::default();
        let cfg = DaemonConfig {
            rounds: 5,
            moves_per_round: 20,
            write_bytes_per_round: 32 * GIB,
            ..Default::default()
        };
        let report = run_daemon(&mut s, &mut bal, &cfg);
        assert_eq!(report.rounds.len(), 5);
        assert!(report.rounds.iter().all(|r| r.written_user_bytes > 0));
        // virtual time advanced whenever data moved
        if report.rounds.iter().any(|r| r.moved_bytes > 0) {
            assert!(report.elapsed > 0.0);
        }
        assert!(s.verify().is_empty());
    }

    /// With the plan pipeline on, every round executes at most the raw
    /// plan's bytes, in at least one phase, and the daemon converges to
    /// the same balance as without the pipeline.
    #[test]
    fn daemon_with_plan_pipeline_matches_raw_balance() {
        let initial = cluster();

        let mut s_raw = initial.clone();
        let mut b_raw = Equilibrium::default();
        let raw = run_daemon(&mut s_raw, &mut b_raw, &DaemonConfig::default());

        let mut s_opt = initial;
        let mut b_opt = Equilibrium::default();
        let cfg = DaemonConfig { plan: crate::plan::PlanConfig::phased(), ..Default::default() };
        let opt = run_daemon(&mut s_opt, &mut b_opt, &cfg);

        // identical planning streams → identical final cluster
        assert_eq!(s_raw.utilizations(), s_opt.utilizations());
        assert_eq!(raw.rounds.len(), opt.rounds.len());
        for (a, b) in raw.rounds.iter().zip(&opt.rounds) {
            assert_eq!(a.planned_moves, b.planned_moves);
            assert!(b.executed_bytes <= b.moved_bytes);
            if b.planned_moves > 0 {
                assert!(b.phases >= 1);
            }
        }
        assert_eq!(opt.plan.rounds, opt.rounds.len());
        assert!(opt.plan.bytes <= opt.plan.raw_bytes);
        assert_eq!(opt.plan.fallbacks, 0, "balancer plans never fall back");
        assert!(s_opt.verify().is_empty());
        // the raw daemon does not engage the pipeline
        assert_eq!(raw.plan.rounds, 0);
    }

    #[test]
    fn moves_per_round_bounds_each_round() {
        let mut s = cluster();
        let mut bal = Equilibrium::default();
        let cfg = DaemonConfig { rounds: 3, moves_per_round: 2, ..Default::default() };
        let report = run_daemon(&mut s, &mut bal, &cfg);
        for r in &report.rounds {
            assert!(r.planned_moves <= 2);
        }
    }
}
