//! The balancing daemon: the operational loop a cluster operator runs.
//!
//! Interleaves (in virtual time) three activities the paper treats
//! separately: clients writing new data (which re-skews the cluster),
//! the balancer planning movements, and the executor carrying the
//! movements out under backfill throttling. This is the "streaming
//! orchestrator with backpressure" role of the Layer-3 coordinator: a
//! round only plans as many movements as the executor can absorb, so
//! balancing never overwhelms recovery I/O.

use crate::balancer::Balancer;
use crate::cluster::{ClusterState, PgId, PoolKind};
use crate::simulator::workload::{Workload, WorkloadModel};
use crate::util::rng::Rng;

use super::events::{Event, EventLog};
use super::executor::{execute_plan, ExecutorConfig};
use super::throttle::Throttle;

/// Daemon tunables.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Number of write→plan→execute rounds.
    pub rounds: usize,
    /// Movement budget per round (backpressure: don't plan more than the
    /// executor can run in a round).
    pub moves_per_round: usize,
    /// User bytes written by clients per round (spread over data pools).
    pub write_bytes_per_round: u64,
    /// How client writes distribute over pools.
    pub workload: WorkloadModel,
    /// When set, the per-round movement budget adapts (AIMD) so each
    /// round's execution fits this many (virtual) seconds.
    pub target_round_seconds: Option<f64>,
    /// Executor limits.
    pub executor: ExecutorConfig,
    /// Workload seed.
    pub seed: u64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            rounds: 10,
            moves_per_round: 50,
            write_bytes_per_round: 0,
            workload: WorkloadModel::Uniform,
            target_round_seconds: None,
            executor: ExecutorConfig::default(),
            seed: 0xDAE_0001,
        }
    }
}

/// Per-round summary.
#[derive(Debug, Clone)]
pub struct RoundReport {
    pub round: usize,
    pub written_user_bytes: u64,
    pub planned_moves: usize,
    pub moved_bytes: u64,
    /// Executor makespan of this round's plan, seconds (virtual).
    pub makespan: f64,
    pub variance_after: f64,
    pub total_avail_after: f64,
    pub converged: bool,
}

/// Daemon output: per-round reports plus the full event log.
#[derive(Debug)]
pub struct DaemonReport {
    pub rounds: Vec<RoundReport>,
    pub log: EventLog,
    /// Total virtual time elapsed, seconds.
    pub elapsed: f64,
}

/// Apply one round of client writes: `user_bytes` spread across
/// user-data pools proportionally to PG count, hitting PGs uniformly
/// (the paper's model: objects hash uniformly into PGs).
pub fn apply_writes(state: &mut ClusterState, user_bytes: u64, rng: &mut Rng) -> u64 {
    let pools: Vec<(u32, u32, f64)> = state
        .pools
        .values()
        .filter(|p| p.kind == PoolKind::UserData)
        .map(|p| (p.id, p.pg_count, p.redundancy.shard_fraction()))
        .collect();
    if pools.is_empty() || user_bytes == 0 {
        return 0;
    }
    let total_pgs: u64 = pools.iter().map(|&(_, c, _)| c as u64).sum();
    let mut written = 0u64;
    for &(pool_id, pg_count, shard_fraction) in &pools {
        let pool_bytes = user_bytes * pg_count as u64 / total_pgs;
        if pool_bytes == 0 {
            continue;
        }
        // hit ~min(pg_count, 32) random PGs with the pool's share
        let hits = (pg_count as usize).min(32);
        let per_pg_user = pool_bytes / hits as u64;
        if per_pg_user == 0 {
            continue;
        }
        for _ in 0..hits {
            let idx = rng.below(pg_count as u64) as u32;
            let per_shard = (per_pg_user as f64 * shard_fraction).round() as u64;
            if per_shard == 0 {
                continue;
            }
            if state.grow_pg(PgId::new(pool_id, idx), per_shard).is_ok() {
                written += per_pg_user;
            }
        }
    }
    written
}

/// Run the daemon loop.
pub fn run_daemon(
    state: &mut ClusterState,
    balancer: &mut dyn Balancer,
    cfg: &DaemonConfig,
) -> DaemonReport {
    let mut rng = Rng::new(cfg.seed);
    let mut workload = Workload::new(cfg.workload.clone(), rng.next_u64());
    let mut throttle = cfg
        .target_round_seconds
        .map(|t| Throttle::new(cfg.moves_per_round, t));
    let mut log = EventLog::default();
    let mut rounds = Vec::new();
    let mut vtime = 0.0f64;

    for round in 0..cfg.rounds {
        log.push(vtime, Event::RoundStarted { round });

        // 1. client writes re-skew the cluster
        let written = workload.write(state, cfg.write_bytes_per_round);
        if written > 0 {
            log.push(vtime, Event::WritesApplied { round, user_bytes: written });
        }

        // 2. plan a bounded batch (backpressure; adaptive when
        //    configured). One `propose_batch` call lets engines amortize
        //    constraint caches and candidate buffers across the whole
        //    round instead of paying per-move setup `budget` times.
        let budget = throttle.as_ref().map(|t| t.budget()).unwrap_or(cfg.moves_per_round);
        let t0 = std::time::Instant::now();
        let plan = balancer.propose_batch(state, budget);
        // a batch shorter than its budget means the balancer ran out of
        // legal, variance-improving moves — the round converged
        let converged = plan.len() < budget;
        let calc = t0.elapsed().as_secs_f64();
        let moved_bytes: u64 = plan.iter().map(|m| m.bytes).sum();
        log.push(
            vtime,
            Event::PlanComputed { round, moves: plan.len(), bytes: moved_bytes, calc_seconds: calc },
        );

        // 3. execute under backfill limits (virtual time advances)
        let report = execute_plan(&plan, &cfg.executor, state.osd_count());
        vtime += report.makespan;
        if let Some(t) = throttle.as_mut() {
            t.observe(report.makespan, plan.len());
        }
        log.push(
            vtime,
            Event::PlanExecuted {
                round,
                makespan: report.makespan,
                peak_concurrency: report.peak_concurrency,
            },
        );
        if converged {
            log.push(vtime, Event::Converged { round });
        }

        rounds.push(RoundReport {
            round,
            written_user_bytes: written,
            planned_moves: plan.len(),
            moved_bytes,
            makespan: report.makespan,
            variance_after: state.utilization_variance(),
            total_avail_after: state.total_max_avail(true),
            converged,
        });

        if converged && cfg.write_bytes_per_round == 0 {
            break; // nothing will change anymore
        }
    }

    DaemonReport { rounds, log, elapsed: vtime }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::Equilibrium;
    use crate::cluster::{ClusterState, Pool};
    use crate::crush::{CrushBuilder, DeviceClass, Level, Rule};
    use crate::util::units::{GIB, TIB};

    fn cluster() -> ClusterState {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..6 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            let size = if h % 2 == 0 { 8 * TIB } else { 4 * TIB };
            b.add_osd_bytes(host, size, DeviceClass::Hdd);
        }
        b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
        ClusterState::build(
            b.build().unwrap(),
            vec![Pool::replicated(1, "p", 3, 64, 0)],
            |_, i| (10 + (i % 7) as u64) * GIB,
        )
    }

    #[test]
    fn apply_writes_accounts_bytes() {
        let mut s = cluster();
        let before = s.total_used();
        let mut rng = Rng::new(1);
        let written = apply_writes(&mut s, 64 * GIB, &mut rng);
        assert!(written > 0);
        // replicated ×3: raw growth is 3× the user bytes actually applied
        assert_eq!(s.total_used() - before, 3 * written_raw(&s, written));
        assert!(s.verify().is_empty());
    }

    // helper: with one replicated pool, per-shard growth equals user
    // bytes per pg; raw = 3 × Σ per-shard
    fn written_raw(_s: &ClusterState, written: u64) -> u64 {
        written
    }

    #[test]
    fn daemon_without_writes_converges_and_stops() {
        let mut s = cluster();
        let mut bal = Equilibrium::default();
        let report = run_daemon(&mut s, &mut bal, &DaemonConfig::default());
        assert!(report.rounds.iter().any(|r| r.converged));
        let last = report.rounds.last().unwrap();
        let first = report.rounds.first().unwrap();
        assert!(last.variance_after <= first.variance_after);
        assert!(!report.log.is_empty());
        assert!(s.verify().is_empty());
    }

    #[test]
    fn daemon_with_writes_keeps_balancing() {
        let mut s = cluster();
        let mut bal = Equilibrium::default();
        let cfg = DaemonConfig {
            rounds: 5,
            moves_per_round: 20,
            write_bytes_per_round: 32 * GIB,
            ..Default::default()
        };
        let report = run_daemon(&mut s, &mut bal, &cfg);
        assert_eq!(report.rounds.len(), 5);
        assert!(report.rounds.iter().all(|r| r.written_user_bytes > 0));
        // virtual time advanced whenever data moved
        if report.rounds.iter().any(|r| r.moved_bytes > 0) {
            assert!(report.elapsed > 0.0);
        }
        assert!(s.verify().is_empty());
    }

    #[test]
    fn moves_per_round_bounds_each_round() {
        let mut s = cluster();
        let mut bal = Equilibrium::default();
        let cfg = DaemonConfig { rounds: 3, moves_per_round: 2, ..Default::default() };
        let report = run_daemon(&mut s, &mut bal, &cfg);
        for r in &report.rounds {
            assert!(r.planned_moves <= 2);
        }
    }
}
