//! Chaos scenario fuzzer: generative testing for the scenario engine.
//!
//! Three pieces compose into tier 4 of the test pyramid
//! (`docs/TESTING.md`):
//!
//! * [`gen`] — a seeded random [`crate::scenario::ScenarioSpec`]
//!   generator: a weighted grammar over all eleven event variants,
//!   structurally valid by construction under four weight
//!   [`Profile`]s.
//! * [`invariant`] — the cluster invariant machine: an [`Invariant`]
//!   trait and a standard suite (fill bounds, state verification,
//!   CRUSH failure domains, balance convergence, clock monotonicity,
//!   upmap consistency) checked after **every** engine event via
//!   [`crate::scenario::ScenarioEngine::with_observer`].
//! * [`corpus`] — the sweep runner: replay generated specs in
//!   parallel (byte-identical at any `EQUILIBRIUM_THREADS`), minimize
//!   failures by prefix bisection, and promote the minimal spec JSON
//!   into `corpus/regressions/`, which `tests/fuzz_corpus.rs` replays
//!   forever after.
//!
//! Design rationale in `docs/rfcs/0005-chaos-fuzzer.md`.

#![warn(missing_docs)]

pub mod corpus;
pub mod gen;
pub mod invariant;

pub use corpus::{
    minimize, promote, replay, replay_in, run_sweep, CaseOutcome, FailingCase, FuzzConfig,
    FuzzReport,
};
pub use gen::{generate_spec, Profile};
pub use invariant::{CheckContext, Invariant, InvariantMachine, Violation};
