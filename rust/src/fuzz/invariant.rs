//! The cluster invariant machine: properties that must hold after
//! *every* scenario event, checked through the
//! [`crate::scenario::ScenarioEngine`] observer hook.
//!
//! Each [`Invariant`] sees the post-event cluster plus the event and its
//! outcome; stateful invariants (convergence, clock monotonicity) carry
//! their own memory between events. The standard suite pins exactly the
//! properties the paper's machinery promises: bounded fill, consistent
//! accounting, CRUSH-rule compliance for every acting set, variance
//! non-increasing across balance rounds, a monotone virtual clock, and
//! an upmap table that describes the acting sets.

use crate::balancer::constraints::rule_slot_constraints;
use crate::cluster::ClusterState;
use crate::crush::{Level, NodeId, OsdId};
use crate::scenario::{EventOutcome, ScenarioEvent};

/// One invariant violation: which check, after which event, and why.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Name of the invariant that fired.
    pub invariant: &'static str,
    /// Zero-based index of the event after which it fired.
    pub event_index: usize,
    /// Human-readable description of the violation.
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] after event {}: {}", self.invariant, self.event_index, self.detail)
    }
}

/// Everything an invariant may look at after one event.
pub struct CheckContext<'a> {
    /// The cluster, post-event.
    pub state: &'a ClusterState,
    /// The event that was just applied.
    pub event: &'a ScenarioEvent,
    /// What the event did.
    pub outcome: &'a EventOutcome,
    /// Virtual time after the event, seconds.
    pub vtime: f64,
    /// Zero-based index of the event in the timeline.
    pub event_index: usize,
}

/// A property of the cluster checked after every event. Implementations
/// may keep state across events (`&mut self`) — e.g. the previous
/// variance or clock reading.
pub trait Invariant {
    /// Short stable name, used in reports and corpus files.
    fn name(&self) -> &'static str;
    /// `Ok(())` if the property holds, `Err(detail)` otherwise.
    fn check(&mut self, cx: &CheckContext<'_>) -> Result<(), String>;
}

/// No device stores more bytes than its physical capacity.
struct NoOverfill;

impl Invariant for NoOverfill {
    fn name(&self) -> &'static str {
        "no-overfill"
    }

    fn check(&mut self, cx: &CheckContext<'_>) -> Result<(), String> {
        for o in 0..cx.state.osd_count() as OsdId {
            let (used, size) = (cx.state.osd_used(o), cx.state.osd_size(o));
            if size > 0 && used > size {
                return Err(format!("osd.{o} holds {used} bytes of {size} capacity"));
            }
        }
        Ok(())
    }
}

/// [`ClusterState::verify`] reports no problems (accounting, shard
/// matrix, aggregates, upmap table — the cluster's own self-checks).
struct VerifyClean;

impl Invariant for VerifyClean {
    fn name(&self) -> &'static str {
        "verify-clean"
    }

    fn check(&mut self, cx: &CheckContext<'_>) -> Result<(), String> {
        let problems = cx.state.verify();
        if problems.is_empty() {
            Ok(())
        } else {
            Err(problems.join("; "))
        }
    }
}

/// Every acting set satisfies its pool's CRUSH rule: device class, take
/// subtree, and failure-domain distinctness at every level of every
/// take/emit block.
struct CrushDomains;

impl Invariant for CrushDomains {
    fn name(&self) -> &'static str {
        "crush-domains"
    }

    fn check(&mut self, cx: &CheckContext<'_>) -> Result<(), String> {
        let state = cx.state;
        for pool in state.pools.values() {
            let rule = state
                .crush
                .rule(pool.rule_id)
                .ok_or_else(|| format!("pool {} references unknown rule {}", pool.id, pool.rule_id))?;
            let blocks = rule_slot_constraints(state, rule, pool.redundancy.shard_count());
            for pg in state.pgs_of_pool(pool.id) {
                for block in &blocks {
                    let osds: Vec<OsdId> = block
                        .slots
                        .clone()
                        .filter_map(|s| pg.acting_osd(s))
                        .collect();
                    for &o in &osds {
                        if let Some(class) = block.class {
                            if state.osd_class(o) != class {
                                return Err(format!(
                                    "pg {} shard on osd.{o} violates class {class:?}",
                                    pg.id()
                                ));
                            }
                        }
                        if !state.crush.in_subtree(o as NodeId, block.take_root) {
                            return Err(format!(
                                "pg {} shard on osd.{o} is outside its take subtree",
                                pg.id()
                            ));
                        }
                    }
                    for &level in &block.distinct_at {
                        if level == Level::Osd {
                            continue;
                        }
                        let mut domains: Vec<NodeId> = Vec::with_capacity(osds.len());
                        for &o in &osds {
                            let Some(d) = state.crush.ancestor_at(o as NodeId, level) else {
                                return Err(format!(
                                    "pg {} shard on osd.{o} has no {level:?} ancestor",
                                    pg.id()
                                ));
                            };
                            if domains.contains(&d) {
                                return Err(format!(
                                    "pg {} places two shards in one {level:?} domain",
                                    pg.id()
                                ));
                            }
                            domains.push(d);
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Utilization variance never increases across a `BalanceRound`: the
/// balancer only applies improving moves, so a round at stable topology
/// (rounds never change topology themselves) must converge. Stateful:
/// remembers the variance after the previous event as the pre-round
/// reading.
struct Convergence {
    last: Option<f64>,
}

impl Invariant for Convergence {
    fn name(&self) -> &'static str {
        "balance-converges"
    }

    fn check(&mut self, cx: &CheckContext<'_>) -> Result<(), String> {
        let var = cx.state.utilization_variance();
        let result = match (cx.event, self.last) {
            (ScenarioEvent::BalanceRound { .. }, Some(prev))
                if var > prev + prev.abs() * 1e-6 + 1e-12 =>
            {
                Err(format!("variance rose across a balance round: {prev:.6e} -> {var:.6e}"))
            }
            _ => Ok(()),
        };
        self.last = Some(var);
        result
    }
}

/// The virtual clock never runs backwards.
struct ClockMonotone {
    last: f64,
}

impl Invariant for ClockMonotone {
    fn name(&self) -> &'static str {
        "clock-monotone"
    }

    fn check(&mut self, cx: &CheckContext<'_>) -> Result<(), String> {
        let prev = self.last;
        self.last = cx.vtime;
        if cx.vtime + 1e-12 < prev {
            Err(format!("virtual clock went backwards: {prev} -> {}", cx.vtime))
        } else {
            Ok(())
        }
    }
}

/// The upmap exception table describes the acting sets: in-range ids,
/// no identity pairs, every replacement acting, one pair per raw
/// source. Intentionally redundant with [`ClusterState::verify`] — the
/// direct check keeps firing even if `verify` regresses.
struct UpmapConsistent;

impl Invariant for UpmapConsistent {
    fn name(&self) -> &'static str {
        "upmap-consistent"
    }

    fn check(&mut self, cx: &CheckContext<'_>) -> Result<(), String> {
        let state = cx.state;
        let n = state.osd_count();
        for pg in state.pgs() {
            let acting: Vec<OsdId> = pg.devices().collect();
            let mut sources: Vec<OsdId> = Vec::new();
            for &(raw, repl) in state.upmap_items(pg.id()) {
                if (raw as usize) >= n || (repl as usize) >= n {
                    return Err(format!("pg {} upmap pair {raw}→{repl} out of range", pg.id()));
                }
                if raw == repl {
                    return Err(format!("pg {} upmap identity pair {raw}→{raw}", pg.id()));
                }
                if !acting.contains(&repl) {
                    return Err(format!(
                        "pg {} upmap replacement osd.{repl} is not acting",
                        pg.id()
                    ));
                }
                if sources.contains(&raw) {
                    return Err(format!("pg {} upmap duplicate source osd.{raw}", pg.id()));
                }
                sources.push(raw);
            }
        }
        Ok(())
    }
}

/// The standard suite wired to run after every engine event — the
/// canonical consumer of [`crate::scenario::ScenarioEngine::with_observer`].
pub struct InvariantMachine {
    invariants: Vec<Box<dyn Invariant>>,
    violations: Vec<Violation>,
    next_index: usize,
}

impl InvariantMachine {
    /// The standard suite (fill, verify, CRUSH domains, convergence,
    /// clock, upmap).
    pub fn standard() -> InvariantMachine {
        InvariantMachine {
            invariants: vec![
                Box::new(NoOverfill),
                Box::new(VerifyClean),
                Box::new(CrushDomains),
                Box::new(Convergence { last: None }),
                Box::new(ClockMonotone { last: 0.0 }),
                Box::new(UpmapConsistent),
            ],
            violations: Vec::new(),
            next_index: 0,
        }
    }

    /// A machine with a custom invariant set (tests, focused replays).
    pub fn with_invariants(invariants: Vec<Box<dyn Invariant>>) -> InvariantMachine {
        InvariantMachine { invariants, violations: Vec::new(), next_index: 0 }
    }

    /// Run every invariant against one post-event snapshot. Shaped to
    /// drop straight into the engine's observer hook:
    /// `engine.with_observer(|s, e, o, t| machine.observe(s, e, o, t))`.
    pub fn observe(
        &mut self,
        state: &ClusterState,
        event: &ScenarioEvent,
        outcome: &EventOutcome,
        vtime: f64,
    ) {
        let cx = CheckContext { state, event, outcome, vtime, event_index: self.next_index };
        for inv in &mut self.invariants {
            if let Err(detail) = inv.check(&cx) {
                self.violations.push(Violation {
                    invariant: inv.name(),
                    event_index: cx.event_index,
                    detail,
                });
            }
        }
        self.next_index += 1;
    }

    /// `true` while no invariant has fired.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Violations recorded so far.
    pub fn violations(&self) -> &[Violation] {
        &self.violations
    }

    /// Consume the machine, yielding its violations.
    pub fn into_violations(self) -> Vec<Violation> {
        self.violations
    }

    /// Number of events observed.
    pub fn events_observed(&self) -> usize {
        self.next_index
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::Equilibrium;
    use crate::generator::clusters;
    use crate::scenario::{ScenarioConfig, ScenarioEngine, ScenarioSpec};
    use crate::simulator::WorkloadModel;
    use crate::util::units::GIB;

    #[test]
    fn clean_timeline_observes_every_event_without_violations() {
        let spec = ScenarioSpec::new("machine-clean", 41)
            .snapshot("initial")
            .workload(WorkloadModel::Uniform, 64 * GIB, 120.0)
            .fail_osd(3)
            .balance(200)
            .snapshot("final");
        let mut state = clusters::demo(spec.seed);
        let mut bal = Equilibrium::default();
        let mut machine = InvariantMachine::standard();
        let config = ScenarioConfig { record_series: false, ..ScenarioConfig::default() };
        let engine = ScenarioEngine::new(&mut state, Some(&mut bal), config, spec.seed)
            .with_observer(|s, e, o, t| machine.observe(s, e, o, t));
        engine.run(&spec).unwrap();
        assert_eq!(machine.events_observed(), 5);
        assert!(machine.is_clean(), "{:?}", machine.violations());
    }

    #[test]
    fn overfill_and_clock_regression_fire() {
        let state = clusters::demo(43);
        let event = ScenarioSpec::new("x", 0).snapshot("s").events.remove(0);
        let outcome = EventOutcome::default();

        // a clock regression fires the monotone invariant
        let mut machine = InvariantMachine::with_invariants(vec![Box::new(ClockMonotone {
            last: 0.0,
        })]);
        machine.observe(&state, &event, &outcome, 10.0);
        machine.observe(&state, &event, &outcome, 5.0);
        assert_eq!(machine.violations().len(), 1);
        assert_eq!(machine.violations()[0].invariant, "clock-monotone");
        assert_eq!(machine.violations()[0].event_index, 1);

        // an overfilled device fires no-overfill (forced via raw writes
        // far beyond the demo cluster's capacity on one pool)
        let mut full = clusters::demo(47);
        let total = full.osd_count() as u64
            * (0..full.osd_count() as OsdId).map(|o| full.osd_size(o)).max().unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        crate::simulator::write_pool(&mut full, 1, total, &mut rng);
        let mut machine = InvariantMachine::with_invariants(vec![Box::new(NoOverfill)]);
        machine.observe(&full, &event, &outcome, 0.0);
        assert!(!machine.is_clean(), "writing {total} bytes must overfill something");
    }

    #[test]
    fn upmap_invariant_fires_on_corruption() {
        let mut s = clusters::demo(53);
        let pg = s.pgs().next().unwrap().id();
        let from = s.pg(pg).unwrap().devices().next().unwrap();
        let to = (0..s.osd_count() as OsdId).find(|&o| !s.pg(pg).unwrap().on(o)).unwrap();
        s.apply_movement(pg, from, to).unwrap();
        let event = ScenarioSpec::new("x", 0).snapshot("s").events.remove(0);
        let mut machine = InvariantMachine::with_invariants(vec![Box::new(UpmapConsistent)]);
        machine.observe(&s, &event, &EventOutcome::default(), 0.0);
        assert!(machine.is_clean(), "{:?}", machine.violations());
    }
}
