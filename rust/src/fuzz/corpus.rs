//! Corpus runner: sweep generated scenarios through the invariant
//! machine, minimize every failure by prefix bisection, and promote
//! the minimal spec into the on-disk regression corpus.
//!
//! The sweep fans out through [`crate::util::parallel::map_collect`]
//! exactly like the fleet runner, so the report is byte-identical at
//! any `EQUILIBRIUM_THREADS` — it contains seeds, event counts, and
//! violations, never wall-clock time.

use std::io;
use std::path::{Path, PathBuf};

use crate::balancer::Equilibrium;
use crate::fuzz::gen::{generate_spec, Profile};
use crate::fuzz::invariant::{InvariantMachine, Violation};
use crate::generator::clusters;
use crate::scenario::{serde, ScenarioConfig, ScenarioEngine, ScenarioSpec};
use crate::util::json::Json;
use crate::util::parallel;

/// Knobs for one fuzz sweep.
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Number of generated cases.
    pub cases: usize,
    /// Case `i` uses seed `seed_base + i`.
    pub seed_base: u64,
    /// Weight profiles to cycle through (case `i` uses `i % len`).
    pub profiles: Vec<Profile>,
    /// Shorter timelines and smaller writes (CI smoke mode).
    pub reduced: bool,
    /// Parallel chunk length for the sweep.
    pub chunk: usize,
}

impl Default for FuzzConfig {
    fn default() -> FuzzConfig {
        FuzzConfig {
            cases: 64,
            seed_base: 0xFA22_0000,
            profiles: Profile::ALL.to_vec(),
            reduced: false,
            chunk: 1,
        }
    }
}

/// What one replay of one spec produced.
#[derive(Debug, Clone, Default)]
pub struct CaseOutcome {
    /// Invariant violations, in event order.
    pub violations: Vec<Violation>,
    /// Engine error, if the run aborted.
    pub error: Option<String>,
}

impl CaseOutcome {
    /// A case fails if the engine errored or any invariant fired.
    pub fn failed(&self) -> bool {
        self.error.is_some() || !self.violations.is_empty()
    }
}

/// A failing case after minimization, ready for promotion.
#[derive(Debug, Clone)]
pub struct FailingCase {
    /// Corpus name (`fuzz-<profile>-<seed>`), also the file stem.
    pub name: String,
    /// Profile that generated it.
    pub profile: Profile,
    /// Generating seed.
    pub seed: u64,
    /// Event count before minimization.
    pub original_events: usize,
    /// The minimal failing spec.
    pub spec: ScenarioSpec,
    /// Outcome of replaying the minimal spec.
    pub outcome: CaseOutcome,
}

/// Deterministic summary of a sweep.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    /// Cases swept.
    pub cases: usize,
    /// First seed.
    pub seed_base: u64,
    /// Smoke mode flag.
    pub reduced: bool,
    /// Profiles cycled through.
    pub profiles: Vec<Profile>,
    /// Total events replayed across all original cases.
    pub total_events: usize,
    /// Every failing case, minimized, in case order.
    pub failing: Vec<FailingCase>,
}

/// Replay `spec` on a fresh demo cluster (seeded by the spec's seed)
/// under the standard invariant suite.
pub fn replay(spec: &ScenarioSpec) -> CaseOutcome {
    replay_in(spec, None)
}

/// Like [`replay`], additionally writing a binary `.eqsnap` state file
/// for every `Snapshot` event in the timeline (the CLI's
/// `scenario run --spec --snapshot-dir` path). `None` replays without
/// touching the filesystem.
pub fn replay_in(spec: &ScenarioSpec, snapshot_dir: Option<&Path>) -> CaseOutcome {
    let mut state = clusters::demo(spec.seed);
    let mut balancer = Equilibrium::default();
    let mut machine = InvariantMachine::standard();
    let config = ScenarioConfig {
        record_series: false,
        snapshot_dir: snapshot_dir.map(Path::to_path_buf),
        ..ScenarioConfig::default()
    };
    let engine = ScenarioEngine::new(&mut state, Some(&mut balancer), config, spec.seed)
        .with_observer(|s, e, o, t| machine.observe(s, e, o, t));
    let error = engine.run(spec).err().map(|e| e.to_string());
    CaseOutcome { violations: machine.into_violations(), error }
}

/// Shrink a failing spec to a locally-minimal failing event prefix by
/// bisection (the same discipline as
/// [`crate::util::prop::check_shrinking`]). Prefixes of a generated
/// timeline are themselves valid timelines, so truncation never turns
/// an invariant violation into a bogus engine error.
pub fn minimize(spec: &ScenarioSpec) -> ScenarioSpec {
    let truncated = |len: usize| -> ScenarioSpec {
        let mut s = spec.clone();
        s.events.truncate(len);
        s
    };
    let mut lo = 0usize;
    let mut hi = spec.events.len();
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if replay(&truncated(mid)).failed() {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    truncated(hi)
}

/// Sweep `cfg.cases` generated specs. Generation and replay fan out in
/// parallel; minimization of the (rare) failures runs serially, in
/// case order, so the report is deterministic.
pub fn run_sweep(cfg: &FuzzConfig) -> FuzzReport {
    let profiles = if cfg.profiles.is_empty() { Profile::ALL.to_vec() } else { cfg.profiles.clone() };
    let results = parallel::map_collect(cfg.cases, cfg.chunk.max(1), |i| {
        let seed = cfg.seed_base + i as u64;
        let profile = profiles[i % profiles.len()];
        let spec = generate_spec(&clusters::demo(seed), seed, profile, cfg.reduced);
        let outcome = replay(&spec);
        (profile, spec, outcome)
    });
    let mut total_events = 0;
    let mut failing = Vec::new();
    for (profile, spec, outcome) in results {
        total_events += spec.events.len();
        if !outcome.failed() {
            continue;
        }
        let minimal = minimize(&spec);
        let minimal_outcome = replay(&minimal);
        failing.push(FailingCase {
            name: spec.name.clone(),
            profile,
            seed: minimal.seed,
            original_events: spec.events.len(),
            spec: minimal,
            outcome: minimal_outcome,
        });
    }
    FuzzReport {
        cases: cfg.cases,
        seed_base: cfg.seed_base,
        reduced: cfg.reduced,
        profiles,
        total_events,
        failing,
    }
}

impl FuzzReport {
    /// Total invariant violations across minimized failing cases.
    pub fn violation_count(&self) -> usize {
        self.failing.iter().map(|f| f.outcome.violations.len()).sum()
    }

    /// The sweep is clean if no case failed.
    pub fn is_clean(&self) -> bool {
        self.failing.is_empty()
    }

    /// Deterministic JSON summary (sorted keys, no wall-clock fields).
    pub fn to_json(&self) -> Json {
        let mut kinds: Vec<(&'static str, u64)> = Vec::new();
        for case in &self.failing {
            for v in &case.outcome.violations {
                match kinds.iter_mut().find(|(k, _)| *k == v.invariant) {
                    Some((_, n)) => *n += 1,
                    None => kinds.push((v.invariant, 1)),
                }
            }
        }
        kinds.sort_by_key(|&(k, _)| k);
        let mut kind_obj = Json::obj();
        for (k, n) in kinds {
            kind_obj = kind_obj.set(k, n);
        }
        let failing: Vec<Json> = self
            .failing
            .iter()
            .map(|case| {
                let violations: Vec<Json> = case
                    .outcome
                    .violations
                    .iter()
                    .map(|v| {
                        Json::obj()
                            .set("detail", v.detail.as_str())
                            .set("event_index", v.event_index)
                            .set("invariant", v.invariant)
                    })
                    .collect();
                Json::obj()
                    .set(
                        "error",
                        match &case.outcome.error {
                            Some(e) => Json::from(e.as_str()),
                            None => Json::Null,
                        },
                    )
                    .set("minimized_events", case.spec.events.len())
                    .set("name", case.name.as_str())
                    .set("original_events", case.original_events)
                    .set("profile", case.profile.name())
                    .set("seed", case.seed)
                    .set("violations", violations)
            })
            .collect();
        Json::obj()
            .set("cases", self.cases)
            .set("events", self.total_events)
            .set("failing", failing)
            .set("profiles", self.profiles.iter().map(|p| Json::from(p.name())).collect::<Vec<_>>())
            .set("reduced", self.reduced)
            .set("seed_base", self.seed_base)
            .set("violation_kinds", kind_obj)
            .set("violations", self.violation_count())
    }

    /// Pretty-printed report with a trailing newline.
    pub fn render(&self) -> String {
        let mut text = self.to_json().pretty();
        text.push('\n');
        text
    }
}

/// Write every minimized failing spec under `dir` as self-contained
/// spec JSON (`<name>.json`); returns the created paths. The corpus
/// replay test (`tests/fuzz_corpus.rs`) picks them up on the next run.
pub fn promote(dir: &Path, report: &FuzzReport) -> io::Result<Vec<PathBuf>> {
    if report.failing.is_empty() {
        return Ok(Vec::new());
    }
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::new();
    for case in &report.failing {
        let path = dir.join(format!("{}.json", case.name));
        std::fs::write(&path, serde::dump(&case.spec))?;
        paths.push(path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduced_sweep_is_clean_and_thread_invariant() {
        let cfg = FuzzConfig { cases: 8, reduced: true, ..FuzzConfig::default() };
        let a = parallel::with_threads(1, || run_sweep(&cfg));
        let b = parallel::with_threads(4, || run_sweep(&cfg));
        assert_eq!(a.render(), b.render(), "report must not depend on thread count");
        assert!(
            a.is_clean(),
            "reduced sweep found violations:\n{}",
            a.render()
        );
        assert_eq!(a.cases, 8);
        assert!(a.total_events > 8 * 8, "suspiciously few events: {}", a.total_events);
    }

    #[test]
    fn replay_flags_engine_errors_as_failures() {
        // a spec that grows a pool that never existed must fail the
        // case (engine error), not panic or pass silently
        let spec = ScenarioSpec::new("bogus-pool", 3).grow_pool(999, 1 << 30);
        let out = replay(&spec);
        assert!(out.failed());
        let err = out.error.expect("engine error surfaced");
        assert!(err.contains("999"), "unexpected error: {err}");
    }

    #[test]
    fn minimize_finds_the_failing_prefix() {
        // build a hand-made failing spec: benign snapshots, then the
        // bogus event, then more benign tail — minimization must cut
        // the tail and keep the prefix through the bogus event
        let spec = ScenarioSpec::new("shrink-me", 5)
            .snapshot("a")
            .snapshot("b")
            .grow_pool(999, 1 << 30)
            .snapshot("c")
            .balance(16)
            .snapshot("d");
        assert!(replay(&spec).failed());
        let minimal = minimize(&spec);
        assert_eq!(minimal.events.len(), 3, "expected prefix through the bogus grow");
        assert!(replay(&minimal).failed());
    }

    #[test]
    fn promotion_writes_replayable_specs() {
        let cfg = FuzzConfig { cases: 2, reduced: true, ..FuzzConfig::default() };
        let mut report = run_sweep(&cfg);
        // force one failing case so promote has something to write
        let spec = ScenarioSpec::new("forced-failure", 9).grow_pool(999, 1 << 30);
        let outcome = replay(&spec);
        report.failing.push(FailingCase {
            name: spec.name.clone(),
            profile: Profile::KitchenSink,
            seed: 9,
            original_events: spec.events.len(),
            spec,
            outcome,
        });
        let dir = std::env::temp_dir().join("equilibrium-fuzz-promote-test");
        let _ = std::fs::remove_dir_all(&dir);
        let paths = promote(&dir, &report).expect("promotion succeeds");
        assert_eq!(paths.len(), 1);
        let loaded = serde::load_file(&paths[0]).expect("promoted spec loads");
        assert!(replay(&loaded).failed(), "promoted spec must still fail");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
