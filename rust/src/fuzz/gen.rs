//! Seeded random [`ScenarioSpec`] generator: a weighted event grammar
//! over all eleven [`ScenarioEvent`] variants, structurally valid by
//! construction.
//!
//! The generator maintains a lightweight model of the cluster it is
//! scripting against (hosts and their devices, live pools with byte
//! estimates, remaining capacity) and refuses to emit an event that
//! would break the engine or the invariant suite for boring reasons:
//! it never fails the last hosts CRUSH needs for an acting set, never
//! references a pool that does not exist, and keeps the projected raw
//! volume under a capacity budget so recovery always has room. Every
//! draw derives from the spec seed — the same seed and profile always
//! produce the same timeline.

use crate::cluster::{ClusterState, HostSpec, Pool};
use crate::crush::{Level, OsdId};
use crate::generator::aging::AgingConfig;
use crate::scenario::{ScenarioEvent, ScenarioSpec};
use crate::simulator::WorkloadModel;
use crate::util::rng::Rng;
use crate::util::units::{GIB, TIB};

/// Keep projected raw bytes under this fraction of live capacity, so
/// failures can always recover and writes never push a device over.
const BUDGET_FRAC: f64 = 0.55;

/// Weight profile of the event grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    /// Device and host failures dominate, with recovery balancing.
    FailureHeavy,
    /// Pool grow/shrink/decommission churn and workload phases.
    ChurnHeavy,
    /// Expansions, new pools, and sustained ingest.
    GrowthHeavy,
    /// Everything, roughly uniformly.
    KitchenSink,
}

impl Profile {
    /// Every profile, in the order the sweep cycles through them.
    pub const ALL: [Profile; 4] =
        [Profile::FailureHeavy, Profile::ChurnHeavy, Profile::GrowthHeavy, Profile::KitchenSink];

    /// Stable name (CLI flag value, report key, corpus file names).
    pub fn name(&self) -> &'static str {
        match self {
            Profile::FailureHeavy => "failure-heavy",
            Profile::ChurnHeavy => "churn-heavy",
            Profile::GrowthHeavy => "growth-heavy",
            Profile::KitchenSink => "kitchen-sink",
        }
    }

    /// Parse a profile name (the CLI's `--profile`).
    pub fn parse(name: &str) -> Option<Profile> {
        Profile::ALL.into_iter().find(|p| p.name() == name)
    }

    /// Event-kind weights, indexed like [`EventKind::ALL`].
    fn weights(&self) -> [f64; 11] {
        // [FailOsd, FailHost, AddHosts, CreatePool, GrowPool, ShrinkPool,
        //  Decommission, Workload, Balance, Age, Snapshot]
        match self {
            Profile::FailureHeavy => [5.0, 3.0, 0.5, 0.5, 1.0, 1.0, 0.25, 1.0, 4.0, 0.25, 0.5],
            Profile::ChurnHeavy => [0.5, 0.25, 0.5, 2.0, 4.0, 4.0, 1.5, 3.0, 3.0, 1.0, 0.5],
            Profile::GrowthHeavy => [0.25, 0.25, 3.0, 3.0, 4.0, 0.5, 0.25, 3.0, 3.0, 1.0, 0.5],
            Profile::KitchenSink => [1.0; 11],
        }
    }
}

/// The eleven event kinds of the grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EventKind {
    FailOsd,
    FailHost,
    AddHosts,
    CreatePool,
    GrowPool,
    ShrinkPool,
    Decommission,
    Workload,
    Balance,
    Age,
    Snapshot,
}

impl EventKind {
    const ALL: [EventKind; 11] = [
        EventKind::FailOsd,
        EventKind::FailHost,
        EventKind::AddHosts,
        EventKind::CreatePool,
        EventKind::GrowPool,
        EventKind::ShrinkPool,
        EventKind::Decommission,
        EventKind::Workload,
        EventKind::Balance,
        EventKind::Age,
        EventKind::Snapshot,
    ];
}

/// A host the generator may fail: name plus its devices.
struct HostModel {
    name: String,
    osds: Vec<OsdId>,
}

/// A live pool the generator may target.
struct PoolModel {
    id: u32,
    user_bytes: u64,
    raw_ratio: f64,
    shard_count: usize,
    fuzz_created: bool,
}

/// The generator's model of the evolving cluster.
struct GenModel {
    hosts: Vec<HostModel>,
    osd_up: Vec<bool>,
    osd_size: Vec<u64>,
    pools: Vec<PoolModel>,
    next_pool_id: u32,
    rule_id: u32,
}

impl GenModel {
    fn from_state(state: &ClusterState) -> GenModel {
        let mut hosts: Vec<HostModel> = state
            .crush
            .buckets
            .values()
            .filter(|b| b.level == Level::Host)
            .map(|b| HostModel { name: b.name.clone(), osds: state.crush.devices_under(b.id, None) })
            .collect();
        hosts.sort_by(|a, b| a.name.cmp(&b.name));
        let n = state.osd_count();
        let pools = state
            .pools
            .values()
            .map(|p| {
                let raw: u64 = state
                    .pgs_of_pool(p.id)
                    .map(|pg| pg.shard_bytes() * pg.devices().count() as u64)
                    .sum();
                PoolModel {
                    id: p.id,
                    user_bytes: (raw as f64 / p.redundancy.raw_ratio()) as u64,
                    raw_ratio: p.redundancy.raw_ratio(),
                    shard_count: p.redundancy.shard_count(),
                    fuzz_created: false,
                }
            })
            .collect();
        let rule_id = state.pools.values().next().map(|p| p.rule_id).unwrap_or(0);
        GenModel {
            hosts,
            osd_up: (0..n as OsdId).map(|o| state.osd_is_up(o)).collect(),
            osd_size: (0..n as OsdId).map(|o| state.osd_size(o)).collect(),
            pools,
            next_pool_id: state.pools.keys().max().map(|&id| id.max(9) + 1).unwrap_or(10),
            rule_id,
        }
    }

    /// Live capacity: bytes on up devices (devices added by `AddHosts`
    /// events are appended to the vectors as they are scripted).
    fn capacity(&self) -> u64 {
        self.osd_size
            .iter()
            .zip(&self.osd_up)
            .filter(|&(_, &up)| up)
            .map(|(&s, _)| s)
            .sum()
    }

    /// Projected raw bytes stored across all pools.
    fn raw_total(&self) -> u64 {
        self.pools.iter().map(|p| (p.user_bytes as f64 * p.raw_ratio) as u64).sum()
    }

    /// Raw-byte headroom under the capacity budget.
    fn headroom(&self) -> u64 {
        (self.capacity() as f64 * BUDGET_FRAC) as u64 - self.raw_total().min(
            (self.capacity() as f64 * BUDGET_FRAC) as u64,
        )
    }

    /// Hosts CRUSH still needs for the widest acting set.
    fn needed_hosts(&self) -> usize {
        self.pools.iter().map(|p| p.shard_count).max().unwrap_or(3)
    }

    /// Number of hosts with at least one up device.
    fn up_hosts(&self) -> usize {
        self.hosts
            .iter()
            .filter(|h| h.osds.iter().any(|&o| self.osd_up[o as usize]))
            .count()
    }

    /// Worst-case raw ratio a user byte can cost (workload spread).
    fn max_ratio(&self) -> f64 {
        self.pools.iter().map(|p| p.raw_ratio).fold(3.0, f64::max)
    }
}

/// Generate a structurally valid random timeline for `base` (the
/// cluster the runner will replay it against). `reduced` scales the
/// event count and write volumes down for CI smoke runs. Deterministic
/// in (`seed`, `profile`, `reduced`).
pub fn generate_spec(
    base: &ClusterState,
    seed: u64,
    profile: Profile,
    reduced: bool,
) -> ScenarioSpec {
    // salted so grammar draws never collide with the engine's own
    // event randomness for the same seed
    let mut rng = Rng::new(seed ^ 0xF022_BA5E_0000_0001);
    let mut model = GenModel::from_state(base);
    let weights = profile.weights();
    let body_events = if reduced { 8 } else { 14 };
    let (vol_lo, vol_hi) = if reduced { (4 * GIB, 64 * GIB) } else { (16 * GIB, 512 * GIB) };

    let name = format!("fuzz-{}-{seed:08x}", profile.name());
    let mut spec = ScenarioSpec::new(&name, seed).snapshot("initial");
    for i in 0..body_events {
        let mut emitted = false;
        // rejection sampling over the weighted grammar: an event kind
        // whose validity rules cannot be met right now is redrawn
        for _ in 0..8 {
            let kind = EventKind::ALL[rng.choose_weighted(&weights).expect("non-empty weights")];
            if let Some(event) = try_emit(kind, &mut model, &mut rng, i, vol_lo, vol_hi) {
                spec = spec.event(event);
                emitted = true;
                break;
            }
        }
        if !emitted {
            // nothing valid drawn — a balance round is always legal
            spec = spec.balance(64);
        }
    }
    spec.balance(256).snapshot("final")
}

fn try_emit(
    kind: EventKind,
    model: &mut GenModel,
    rng: &mut Rng,
    index: usize,
    vol_lo: u64,
    vol_hi: u64,
) -> Option<ScenarioEvent> {
    match kind {
        EventKind::FailOsd => {
            // candidate devices: up, on a modelled host, and with both
            // enough surviving hosts for CRUSH and enough surviving
            // capacity for recovery under the budget
            let raw = model.raw_total();
            let budget_cap = |remaining: u64| (remaining as f64 * BUDGET_FRAC) as u64;
            let candidates: Vec<OsdId> = model
                .hosts
                .iter()
                .flat_map(|h| h.osds.iter().copied())
                .filter(|&o| model.osd_up[o as usize])
                .filter(|&o| {
                    let host = model.hosts.iter().find(|h| h.osds.contains(&o)).expect("host");
                    let host_survives =
                        host.osds.iter().any(|&x| x != o && model.osd_up[x as usize]);
                    let hosts_after = model.up_hosts() - usize::from(!host_survives);
                    let cap_after = model.capacity() - model.osd_size[o as usize];
                    hosts_after >= model.needed_hosts() && raw <= budget_cap(cap_after)
                })
                .collect();
            let &osd = rng.choose(&candidates)?;
            model.osd_up[osd as usize] = false;
            Some(ScenarioEvent::FailOsd { osd })
        }
        EventKind::FailHost => {
            let raw = model.raw_total();
            let candidates: Vec<usize> = (0..model.hosts.len())
                .filter(|&h| {
                    let host = &model.hosts[h];
                    let host_up: Vec<OsdId> = host
                        .osds
                        .iter()
                        .copied()
                        .filter(|&o| model.osd_up[o as usize])
                        .collect();
                    if host_up.is_empty() {
                        return false; // failing a dead host is a no-op
                    }
                    let lost: u64 = host_up.iter().map(|&o| model.osd_size[o as usize]).sum();
                    let cap_after = model.capacity() - lost;
                    model.up_hosts() - 1 >= model.needed_hosts()
                        && raw <= (cap_after as f64 * BUDGET_FRAC) as u64
                })
                .collect();
            let &h = rng.choose(&candidates)?;
            for o in model.hosts[h].osds.clone() {
                model.osd_up[o as usize] = false;
            }
            Some(ScenarioEvent::FailHost { host: model.hosts[h].name.clone() })
        }
        EventKind::AddHosts => {
            let hosts = 1 + rng.index(2);
            let osds_per_host = 1 + rng.index(3);
            let osd_bytes = [2 * TIB, 4 * TIB, 8 * TIB][rng.index(3)];
            // new devices extend the model's capacity; they are never
            // failure candidates (their bucket names are assigned at
            // apply time), which only errs on the safe side
            for _ in 0..hosts * osds_per_host {
                model.osd_up.push(true);
                model.osd_size.push(osd_bytes);
            }
            Some(ScenarioEvent::AddHosts {
                spec: HostSpec::hdd(hosts, osds_per_host, osd_bytes),
            })
        }
        EventKind::CreatePool => {
            let id = model.next_pool_id;
            // replicated 3× mostly; sometimes EC 2+1 (same 3-slot width,
            // so the host budget CRUSH needs does not grow)
            let (pool, ratio, shards) = if rng.chance(0.2) {
                (Pool::erasure(id, &format!("fz{id}"), 2, 1, 16, model.rule_id), 1.5, 3)
            } else {
                let pg_count = [8u32, 16, 32][rng.index(3)];
                (Pool::replicated(id, &format!("fz{id}"), 3, pg_count, model.rule_id), 3.0, 3)
            };
            let max_user = ((model.headroom() as f64 / ratio) as u64 / 2).min(vol_hi);
            if max_user < vol_lo {
                return None;
            }
            let user_bytes = rng.range_u64(vol_lo, max_user);
            model.next_pool_id += 1;
            model.pools.push(PoolModel {
                id,
                user_bytes,
                raw_ratio: ratio,
                shard_count: shards,
                fuzz_created: true,
            });
            Some(ScenarioEvent::CreatePool { pool, user_bytes })
        }
        EventKind::GrowPool => {
            let p = rng.index(model.pools.len());
            let ratio = model.pools[p].raw_ratio;
            let max_user = ((model.headroom() as f64 / ratio) as u64 / 2).min(vol_hi);
            if max_user < vol_lo {
                return None;
            }
            let user_bytes = rng.range_u64(vol_lo, max_user);
            model.pools[p].user_bytes += user_bytes;
            Some(ScenarioEvent::GrowPool { pool: model.pools[p].id, user_bytes })
        }
        EventKind::ShrinkPool => {
            let candidates: Vec<usize> = (0..model.pools.len())
                .filter(|&p| model.pools[p].user_bytes > 2 * GIB)
                .collect();
            let &p = rng.choose(&candidates)?;
            let user_bytes = rng.range_u64(GIB, model.pools[p].user_bytes / 2);
            model.pools[p].user_bytes -= user_bytes;
            Some(ScenarioEvent::ShrinkPool { pool: model.pools[p].id, user_bytes })
        }
        EventKind::Decommission => {
            let candidates: Vec<usize> =
                (0..model.pools.len()).filter(|&p| model.pools[p].fuzz_created).collect();
            let &p = rng.choose(&candidates)?;
            // drop it from the model so no later event references it
            let pool = model.pools.remove(p).id;
            Some(ScenarioEvent::DecommissionPool { pool })
        }
        EventKind::Workload => {
            let max_user = ((model.headroom() as f64 / model.max_ratio()) as u64 / 2).min(vol_hi);
            if max_user < vol_lo {
                return None;
            }
            let user_bytes = rng.range_u64(vol_lo, max_user);
            let pool_ids: Vec<u32> = model.pools.iter().map(|p| p.id).collect();
            let workload_model = match rng.index(3) {
                0 => WorkloadModel::Uniform,
                1 => WorkloadModel::ZipfPools { exponent: rng.range_f64(0.5, 1.5) },
                _ => WorkloadModel::Hotspot {
                    pool: *rng.choose(&pool_ids)?,
                    fraction: rng.range_f64(0.5, 0.9),
                },
            };
            // conservative: attribute the whole phase at the worst ratio
            let spread = (user_bytes as f64 / model.pools.len().max(1) as f64) as u64;
            for p in &mut model.pools {
                p.user_bytes += spread;
            }
            Some(ScenarioEvent::WorkloadPhase {
                model: workload_model,
                user_bytes,
                duration: rng.range_f64(30.0, 600.0),
            })
        }
        EventKind::Balance => {
            // max_moves 0 is a deliberate edge case the engine must absorb
            let max_moves = [0usize, 16, 64, 256][rng.choose_weighted(&[0.1, 0.3, 0.3, 0.3])?];
            Some(ScenarioEvent::BalanceRound { max_moves })
        }
        EventKind::Age => {
            let epochs = 1 + rng.index(3);
            let max_grow = rng.range_f64(0.02, 0.08);
            let growth_bound = (1.0 + max_grow).powi(epochs as i32);
            let projected = (model.raw_total() as f64 * growth_bound) as u64;
            if projected > (model.capacity() as f64 * BUDGET_FRAC) as u64 {
                return None;
            }
            for p in &mut model.pools {
                p.user_bytes = (p.user_bytes as f64 * growth_bound) as u64;
            }
            Some(ScenarioEvent::Age {
                cfg: AgingConfig {
                    epochs,
                    max_grow,
                    max_shrink: rng.range_f64(0.02, 0.06),
                    dormant_prob: rng.range_f64(0.2, 0.6),
                },
            })
        }
        EventKind::Snapshot => Some(ScenarioEvent::Snapshot { label: format!("s{index}") }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::clusters;

    #[test]
    fn generation_is_deterministic_per_seed_and_profile() {
        let base = clusters::demo(7);
        for profile in Profile::ALL {
            let a = generate_spec(&base, 7, profile, true);
            let b = generate_spec(&base, 7, profile, true);
            assert_eq!(crate::scenario::serde::dump(&a), crate::scenario::serde::dump(&b));
            assert_eq!(a.name, format!("fuzz-{}-{:08x}", profile.name(), 7));
            // snapshot bookends plus the body
            assert!(a.events.len() >= 10, "{} events", a.events.len());
        }
        let c = generate_spec(&base, 8, Profile::KitchenSink, true);
        let d = generate_spec(&base, 7, Profile::KitchenSink, true);
        assert_ne!(
            crate::scenario::serde::dump(&c),
            crate::scenario::serde::dump(&d),
            "different seeds must differ"
        );
    }

    #[test]
    fn profiles_parse_and_roundtrip_names() {
        for p in Profile::ALL {
            assert_eq!(Profile::parse(p.name()), Some(p));
        }
        assert_eq!(Profile::parse("nope"), None);
    }

    #[test]
    fn generated_failures_never_exhaust_crush_hosts() {
        // drive the failure-heavy profile across many seeds and count
        // host failures scripted into each timeline: the demo cluster
        // has 6 hosts and 3-wide acting sets, so at most 3 may ever fail
        let base = clusters::demo(1);
        for seed in 0..32u64 {
            let spec = generate_spec(&base, seed, Profile::FailureHeavy, true);
            let failed_hosts = spec
                .events
                .iter()
                .filter(|e| matches!(e, ScenarioEvent::FailHost { .. }))
                .count();
            assert!(failed_hosts <= 3, "seed {seed} scripted {failed_hosts} host failures");
            for e in &spec.events {
                if let ScenarioEvent::FailOsd { osd } = e {
                    assert!((*osd as usize) < base.osd_count(), "seed {seed} fails unknown osd");
                }
            }
        }
    }
}
