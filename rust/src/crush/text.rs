//! CRUSH map decompilation — `crushtool --decompile`-style text output
//! for auditing generated maps (operators read these, diff them, and
//! paste fragments into tickets).

use std::fmt::Write as _;

use super::types::{CrushMap, Level, NodeId, Step};

/// Render the whole map in crushtool-like syntax.
pub fn decompile(map: &CrushMap) -> String {
    let mut out = String::new();

    out.push_str("# begin crush map (equilibrium decompile)\n\n# devices\n");
    for d in &map.devices {
        let _ = writeln!(out, "device {} osd.{} class {}", d.id, d.id, d.class.as_str());
    }

    out.push_str("\n# buckets\n");
    // leaf-ward order: deepest levels first so references are defined
    // before use, like crushtool prints
    let mut buckets: Vec<&super::types::Bucket> = map.buckets.values().collect();
    buckets.sort_by_key(|b| (b.level.rank(), b.id));
    for b in buckets {
        let _ = writeln!(out, "{} {} {{", b.level.as_str(), b.name);
        let _ = writeln!(out, "\tid {}", b.id);
        let _ = writeln!(out, "\talg straw2");
        for &c in &b.children {
            if c >= 0 {
                let d = &map.devices[c as usize];
                let _ = writeln!(out, "\titem osd.{} weight {:.3}", c, d.weight);
            } else if let Some(child) = map.buckets.get(&c) {
                let _ = writeln!(
                    out,
                    "\titem {} weight {:.3}",
                    child.name,
                    map.weight_of(c, None)
                );
            }
        }
        out.push_str("}\n");
    }

    out.push_str("\n# rules\n");
    for r in map.rules.values() {
        let _ = writeln!(out, "rule {} {{", r.name);
        let _ = writeln!(out, "\tid {}", r.id);
        for s in &r.steps {
            match s {
                Step::Take { root, class } => {
                    let _ = match class {
                        Some(c) => writeln!(out, "\tstep take {} class {}", root, c.as_str()),
                        None => writeln!(out, "\tstep take {root}"),
                    };
                }
                Step::ChooseFirstN { num, level } => {
                    let _ = writeln!(out, "\tstep choose firstn {} type {}", num, level.as_str());
                }
                Step::ChooseLeafFirstN { num, level } => {
                    let _ =
                        writeln!(out, "\tstep chooseleaf firstn {} type {}", num, level.as_str());
                }
                Step::ChooseIndep { num, level } => {
                    let _ = writeln!(out, "\tstep choose indep {} type {}", num, level.as_str());
                }
                Step::ChooseLeafIndep { num, level } => {
                    let _ =
                        writeln!(out, "\tstep chooseleaf indep {} type {}", num, level.as_str());
                }
                Step::Emit => out.push_str("\tstep emit\n"),
            }
        }
        out.push_str("}\n");
    }
    out.push_str("\n# end crush map\n");
    out
}

/// Short one-line-per-node tree rendering (for `df`-style tooling).
pub fn tree(map: &CrushMap) -> String {
    let mut out = String::new();
    let roots: Vec<NodeId> = map
        .buckets
        .values()
        .filter(|b| b.level == Level::Root)
        .map(|b| b.id)
        .collect();
    for root in roots {
        render_node(map, root, 0, &mut out);
    }
    out
}

fn render_node(map: &CrushMap, node: NodeId, depth: usize, out: &mut String) {
    let indent = "  ".repeat(depth);
    if node >= 0 {
        let d = &map.devices[node as usize];
        let _ = writeln!(
            out,
            "{indent}osd.{} ({}, weight {:.3})",
            d.id,
            d.class.as_str(),
            d.weight
        );
        return;
    }
    if let Some(b) = map.buckets.get(&node) {
        let _ = writeln!(
            out,
            "{indent}{} {} (weight {:.3})",
            b.level.as_str(),
            b.name,
            map.weight_of(node, None)
        );
        for &c in &b.children {
            render_node(map, c, depth + 1, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crush::{CrushBuilder, DeviceClass, Rule};
    use crate::util::units::TIB;

    fn sample() -> CrushMap {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        let h = b.add_bucket("host0", Level::Host, root);
        b.add_osd_bytes(h, 4 * TIB, DeviceClass::Hdd);
        b.add_osd_bytes(h, TIB, DeviceClass::Ssd);
        b.add_rule(Rule::replicated(0, "repl", "default", Some(DeviceClass::Hdd), Level::Host));
        b.add_rule(Rule::erasure(1, "ec", "default", None, Level::Host));
        b.build().unwrap()
    }

    #[test]
    fn decompile_contains_all_sections() {
        let text = decompile(&sample());
        assert!(text.contains("device 0 osd.0 class hdd"));
        assert!(text.contains("host host0 {"));
        assert!(text.contains("root default {"));
        assert!(text.contains("item host0 weight"));
        assert!(text.contains("rule repl {"));
        assert!(text.contains("step take default class hdd"));
        assert!(text.contains("step chooseleaf firstn 0 type host"));
        assert!(text.contains("step chooseleaf indep 0 type host"));
        assert!(text.contains("step emit"));
    }

    #[test]
    fn hosts_print_before_roots() {
        let text = decompile(&sample());
        let host_pos = text.find("host host0").unwrap();
        let root_pos = text.find("root default").unwrap();
        assert!(host_pos < root_pos, "leaf-ward buckets must be defined first");
    }

    #[test]
    fn tree_shows_hierarchy() {
        let t = tree(&sample());
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines[0].starts_with("root default"));
        assert!(lines[1].trim_start().starts_with("host host0"));
        assert!(lines[2].trim_start().starts_with("osd.0"));
        assert_eq!(lines.len(), 4);
    }
}
