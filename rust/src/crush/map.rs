//! CRUSH rule execution: mapping a placement-group input to an ordered
//! set of devices.
//!
//! Implements the `firstn` (replicated) and `indep` (erasure-coded)
//! selection strategies with collision/duplicate retry, failure-domain
//! distinctness, device-class filtering and multi-take rules, following
//! the structure of Ceph's `crush_do_rule`/`crush_choose_firstn`/
//! `crush_choose_indep`.

use super::hash::hash32_2;
use super::straw2::bucket_choose;
use super::types::{CrushMap, DeviceClass, Level, NodeId, OsdId, Rule, Step};

/// Maximum total descent attempts per replica slot (Ceph's
/// `choose_total_tries` default is 50).
pub const TOTAL_TRIES: u32 = 50;

/// Compute the CRUSH input value for a placement group. Mirrors Ceph's
/// `pg → pps` seeding: a stable hash of (pg index, pool id).
#[inline]
pub fn pg_input(pool_id: u32, pg_index: u32) -> u32 {
    hash32_2(pg_index, pool_id)
}

/// The result of running a rule: one entry per replica/EC slot. Holes
/// (`None`) are possible for `indep` rules when a slot cannot be filled;
/// `firstn` failures shorten the vector instead, which we normalize to
/// trailing holes so the caller always sees `result_size` slots.
pub type Mapping = Vec<Option<OsdId>>;

/// Execute `rule` for input `x`, producing `result_size` slots.
pub fn map_rule(map: &CrushMap, rule: &Rule, x: u32, result_size: usize) -> Mapping {
    let mut result: Vec<Option<OsdId>> = Vec::with_capacity(result_size);
    let mut chosen_devices: Vec<OsdId> = Vec::new();
    let mut work: Vec<NodeId> = Vec::new();
    let mut class: Option<DeviceClass> = None;

    for step in &rule.steps {
        match step {
            Step::Take { root, class: c } => {
                work.clear();
                if let Some(&node) = map.bucket_by_name.get(root) {
                    work.push(node);
                }
                class = *c;
            }
            Step::ChooseFirstN { num, level } => {
                let numrep = resolve_num(*num, result_size, result.len());
                let mut next = Vec::new();
                for &parent in &work {
                    next.extend(choose_firstn(
                        map,
                        parent,
                        class,
                        *level,
                        numrep,
                        x,
                        false,
                        &mut chosen_devices,
                    ));
                }
                work = next;
            }
            Step::ChooseLeafFirstN { num, level } => {
                let numrep = resolve_num(*num, result_size, result.len());
                let mut next = Vec::new();
                for &parent in &work {
                    next.extend(choose_firstn(
                        map,
                        parent,
                        class,
                        *level,
                        numrep,
                        x,
                        true,
                        &mut chosen_devices,
                    ));
                }
                work = next;
            }
            Step::ChooseIndep { num, level } => {
                let numrep = resolve_num(*num, result_size, result.len());
                let mut next = Vec::new();
                for &parent in &work {
                    next.extend(choose_indep(
                        map,
                        parent,
                        class,
                        *level,
                        numrep,
                        x,
                        false,
                        &mut chosen_devices,
                    ));
                }
                work = next;
            }
            Step::ChooseLeafIndep { num, level } => {
                let numrep = resolve_num(*num, result_size, result.len());
                let mut next = Vec::new();
                for &parent in &work {
                    next.extend(choose_indep(
                        map,
                        parent,
                        class,
                        *level,
                        numrep,
                        x,
                        true,
                        &mut chosen_devices,
                    ));
                }
                work = next;
            }
            Step::Emit => {
                for node in work.drain(..) {
                    if node >= 0 {
                        result.push(Some(node as OsdId));
                    } else {
                        // emitting a bucket is a rule-authoring error; emit
                        // a hole rather than panic
                        result.push(None);
                    }
                }
            }
        }
        if result.len() >= result_size {
            break;
        }
    }

    result.truncate(result_size);
    while result.len() < result_size {
        result.push(None);
    }
    result
}

/// Resolve a step's `num` field against the pool size (Ceph semantics:
/// 0 = "as many as still needed", negative = "all but |num|").
fn resolve_num(num: i32, result_size: usize, already: usize) -> usize {
    let remaining = result_size.saturating_sub(already);
    if num == 0 {
        remaining
    } else if num > 0 {
        (num as usize).min(remaining)
    } else {
        // Ceph: numrep = result_max + arg (arg negative), i.e. "all but
        // |num|" of the pool size — independent of what prior emits used,
        // but never more than the remaining slots.
        result_size
            .saturating_sub(num.unsigned_abs() as usize)
            .min(remaining)
    }
}

/// Descend from `node` until reaching a node at `level` (buckets only;
/// level Osd means descend to a device). Returns None on a dead end.
fn descend_to_level(
    map: &CrushMap,
    mut node: NodeId,
    level: Level,
    class: Option<DeviceClass>,
    x: u32,
    r: u32,
) -> Option<NodeId> {
    loop {
        let cur_level = map.level_of(node)?;
        if cur_level == level {
            return Some(node);
        }
        if cur_level < level || node >= 0 {
            return None; // overshot: the tree skips this level
        }
        node = bucket_choose(map, node, x, r, class)?;
    }
}

/// Descend from a failure-domain bucket all the way to a device.
fn descend_to_device(
    map: &CrushMap,
    node: NodeId,
    class: Option<DeviceClass>,
    x: u32,
    r: u32,
) -> Option<OsdId> {
    let mut cur = node;
    while cur < 0 {
        cur = bucket_choose(map, cur, x, r, class)?;
    }
    let d = &map.devices[cur as usize];
    if let Some(c) = class {
        if d.class != c {
            return None;
        }
    }
    if d.weight <= 0.0 {
        return None;
    }
    Some(cur as OsdId)
}

/// firstn selection: `numrep` distinct failure domains under `parent`;
/// on failure the result is simply shorter (replicated pools degrade).
#[allow(clippy::too_many_arguments)]
fn choose_firstn(
    map: &CrushMap,
    parent: NodeId,
    class: Option<DeviceClass>,
    level: Level,
    numrep: usize,
    x: u32,
    chooseleaf: bool,
    chosen_devices: &mut Vec<OsdId>,
) -> Vec<NodeId> {
    let mut out: Vec<NodeId> = Vec::with_capacity(numrep);
    let mut chosen_domains: Vec<NodeId> = Vec::new();

    for rep in 0..numrep as u32 {
        let mut ftotal = 0u32;
        'attempts: while ftotal < TOTAL_TRIES {
            let r = rep + ftotal;
            ftotal += 1;
            let Some(domain) = descend_to_level(map, parent, level, class, x, r) else {
                continue 'attempts;
            };
            if chosen_domains.contains(&domain) {
                continue 'attempts;
            }
            if chooseleaf {
                // inner retry loop for the leaf descent; stride by numrep
                // so different replica slots explore disjoint r-sequences
                let mut dev = None;
                for leaf_try in 0..TOTAL_TRIES {
                    let r2 = rep + leaf_try * numrep.max(1) as u32;
                    if let Some(d) = descend_to_device(map, domain, class, x, r2) {
                        if !chosen_devices.contains(&d) {
                            dev = Some(d);
                            break;
                        }
                    }
                }
                let Some(d) = dev else { continue 'attempts };
                chosen_domains.push(domain);
                chosen_devices.push(d);
                out.push(d as NodeId);
            } else {
                if domain >= 0 && chosen_devices.contains(&(domain as OsdId)) {
                    continue 'attempts;
                }
                chosen_domains.push(domain);
                if domain >= 0 {
                    chosen_devices.push(domain as OsdId);
                }
                out.push(domain);
            }
            break 'attempts;
        }
    }
    out
}

/// indep selection: positional, holes stay holes (erasure-coded pools
/// must not shift shards between slots).
#[allow(clippy::too_many_arguments)]
fn choose_indep(
    map: &CrushMap,
    parent: NodeId,
    class: Option<DeviceClass>,
    level: Level,
    numrep: usize,
    x: u32,
    chooseleaf: bool,
    chosen_devices: &mut Vec<OsdId>,
) -> Vec<NodeId> {
    let mut out: Vec<NodeId> = vec![i32::MIN; numrep]; // sentinel = hole
    let mut chosen_domains: Vec<NodeId> = Vec::new();
    let stride = numrep.max(1) as u32;

    for rep in 0..numrep as u32 {
        'attempts: for ftotal in 0..TOTAL_TRIES {
            // each slot has a disjoint retry sequence: slot stability
            let r = rep + ftotal * stride;
            let Some(domain) = descend_to_level(map, parent, level, class, x, r) else {
                continue 'attempts;
            };
            if chosen_domains.contains(&domain) {
                continue 'attempts;
            }
            if chooseleaf {
                let mut dev = None;
                for leaf_try in 0..TOTAL_TRIES {
                    let r2 = rep + leaf_try * stride;
                    if let Some(d) = descend_to_device(map, domain, class, x, r2) {
                        if !chosen_devices.contains(&d) {
                            dev = Some(d);
                            break;
                        }
                    }
                }
                let Some(d) = dev else { continue 'attempts };
                chosen_domains.push(domain);
                chosen_devices.push(d);
                out[rep as usize] = d as NodeId;
            } else {
                if domain >= 0 && chosen_devices.contains(&(domain as OsdId)) {
                    continue 'attempts;
                }
                chosen_domains.push(domain);
                if domain >= 0 {
                    chosen_devices.push(domain as OsdId);
                }
                out[rep as usize] = domain;
            }
            break 'attempts;
        }
    }

    // holes: sentinel → keep position but caller sees None via map_rule's
    // emit (i32::MIN is never a valid node)
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crush::builder::CrushBuilder;
    use crate::crush::types::Rule;
    use crate::util::units::TIB;

    /// 6 hosts × 4 OSDs of 4 TiB, one root.
    fn uniform_map() -> CrushMap {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..6 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            for _ in 0..4 {
                b.add_osd_bytes(host, 4 * TIB, DeviceClass::Hdd);
            }
        }
        b.add_rule(Rule::replicated(0, "repl", "default", None, Level::Host));
        b.add_rule(Rule::erasure(1, "ec", "default", None, Level::Host));
        b.build().unwrap()
    }

    #[test]
    fn replicated_mapping_gives_distinct_hosts() {
        let m = uniform_map();
        let rule = m.rule(0).unwrap();
        for pg in 0..500 {
            let x = pg_input(1, pg);
            let slots = map_rule(&m, rule, x, 3);
            let devs: Vec<OsdId> = slots.iter().filter_map(|s| *s).collect();
            assert_eq!(devs.len(), 3, "pg {pg}: {slots:?}");
            let hosts: Vec<NodeId> = devs
                .iter()
                .map(|&d| m.ancestor_at(d as NodeId, Level::Host).unwrap())
                .collect();
            let mut uniq = hosts.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "pg {pg}: hosts {hosts:?} not distinct");
        }
    }

    #[test]
    fn mapping_is_deterministic() {
        let m = uniform_map();
        let rule = m.rule(0).unwrap();
        for pg in 0..50 {
            let x = pg_input(3, pg);
            assert_eq!(map_rule(&m, rule, x, 3), map_rule(&m, rule, x, 3));
        }
    }

    #[test]
    fn ec_mapping_fills_all_slots_when_possible() {
        let m = uniform_map();
        let rule = m.rule(1).unwrap();
        for pg in 0..200 {
            let x = pg_input(2, pg);
            let slots = map_rule(&m, rule, x, 5);
            assert_eq!(slots.len(), 5);
            let devs: Vec<OsdId> = slots.iter().filter_map(|s| *s).collect();
            assert_eq!(devs.len(), 5, "pg {pg}: {slots:?}");
            let mut uniq = devs.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 5);
        }
    }

    #[test]
    fn ec_with_more_slots_than_domains_leaves_holes() {
        // 3 hosts but k+m = 5 with host failure domain → 2 holes
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..3 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            b.add_osd_bytes(host, 4 * TIB, DeviceClass::Hdd);
        }
        b.add_rule(Rule::erasure(1, "ec", "default", None, Level::Host));
        let m = b.build().unwrap();
        let slots = map_rule(&m, m.rule(1).unwrap(), pg_input(1, 1), 5);
        let filled = slots.iter().filter(|s| s.is_some()).count();
        assert_eq!(filled, 3, "{slots:?}");
        assert_eq!(slots.len(), 5);
    }

    #[test]
    fn distribution_tracks_osd_weights() {
        // hosts with 2x weight get ~2x the shards
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..4 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            let size = if h < 2 { 8 * TIB } else { 4 * TIB };
            b.add_osd_bytes(host, size, DeviceClass::Hdd);
        }
        b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
        let m = b.build().unwrap();
        let rule = m.rule(0).unwrap();
        let mut counts = [0usize; 4];
        let pgs = 6000u32;
        for pg in 0..pgs {
            for s in map_rule(&m, rule, pg_input(7, pg), 2).iter().flatten() {
                counts[*s as usize] += 1;
            }
        }
        // big OSDs (0,1) should hold roughly 8/12 of all shards. Replica
        // distinctness (2 of 4 hosts per PG) compresses the spread, so
        // allow generous tolerance — the balancers exist precisely because
        // CRUSH is only approximately weight-proportional.
        let total: usize = counts.iter().sum();
        let big = (counts[0] + counts[1]) as f64 / total as f64;
        assert!((0.55..0.75).contains(&big), "big-host share {big:.3}");
    }

    #[test]
    fn class_restricted_rule_only_uses_class_devices() {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..4 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            b.add_osd_bytes(host, 4 * TIB, DeviceClass::Hdd);
            b.add_osd_bytes(host, TIB, DeviceClass::Ssd);
        }
        b.add_rule(Rule::replicated(0, "ssd", "default", Some(DeviceClass::Ssd), Level::Host));
        let m = b.build().unwrap();
        let rule = m.rule(0).unwrap();
        for pg in 0..300 {
            for d in map_rule(&m, rule, pg_input(9, pg), 3).iter().flatten() {
                assert_eq!(m.devices[*d as usize].class, DeviceClass::Ssd);
            }
        }
    }

    #[test]
    fn hybrid_rule_mixes_classes_in_order() {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..6 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            b.add_osd_bytes(host, 4 * TIB, DeviceClass::Hdd);
            b.add_osd_bytes(host, TIB, DeviceClass::Ssd);
        }
        b.add_rule(Rule::hybrid(
            0,
            "hybrid",
            "default",
            DeviceClass::Ssd,
            1,
            DeviceClass::Hdd,
            Level::Host,
        ));
        let m = b.build().unwrap();
        let rule = m.rule(0).unwrap();
        for pg in 0..300 {
            let slots = map_rule(&m, rule, pg_input(4, pg), 3);
            let devs: Vec<OsdId> = slots.iter().filter_map(|s| *s).collect();
            assert_eq!(devs.len(), 3, "pg {pg}: {slots:?}");
            assert_eq!(m.devices[devs[0] as usize].class, DeviceClass::Ssd, "slot 0 is SSD");
            assert_eq!(m.devices[devs[1] as usize].class, DeviceClass::Hdd);
            assert_eq!(m.devices[devs[2] as usize].class, DeviceClass::Hdd);
            let mut uniq = devs.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "no device reuse across takes");
        }
    }

    #[test]
    fn weight_change_moves_limited_data() {
        // straw2 through the whole stack: growing one host moves shards
        // only toward it
        let build = |w0: u64| {
            let mut b = CrushBuilder::new();
            let root = b.add_root("default");
            for h in 0..5 {
                let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
                let size = if h == 0 { w0 } else { 4 * TIB };
                b.add_osd_bytes(host, size, DeviceClass::Hdd);
            }
            b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
            b.build().unwrap()
        };
        let m1 = build(4 * TIB);
        let m2 = build(8 * TIB);
        let r1 = m1.rule(0).unwrap();
        let mut moved_toward = 0;
        let mut moved_elsewhere = 0;
        for pg in 0..2000 {
            let x = pg_input(5, pg);
            let a = map_rule(&m1, r1, x, 1)[0];
            let b = map_rule(&m2, m2.rule(0).unwrap(), x, 1)[0];
            if a != b {
                if b == Some(0) {
                    moved_toward += 1;
                } else {
                    moved_elsewhere += 1;
                }
            }
        }
        assert!(moved_toward > 0);
        assert_eq!(moved_elsewhere, 0, "single-replica movement must flow to the grown host");
    }

    #[test]
    fn resolve_num_semantics() {
        assert_eq!(resolve_num(0, 3, 0), 3);
        assert_eq!(resolve_num(2, 3, 0), 2);
        assert_eq!(resolve_num(5, 3, 0), 3);
        assert_eq!(resolve_num(-1, 3, 1), 2); // "all but 1" of pool size 3
        assert_eq!(resolve_num(0, 3, 1), 2);
    }

    #[test]
    fn pg_input_is_stable_and_spread() {
        assert_eq!(pg_input(1, 2), pg_input(1, 2));
        assert_ne!(pg_input(1, 2), pg_input(2, 1));
        let mut seen = std::collections::BTreeSet::new();
        for pg in 0..1000 {
            seen.insert(pg_input(1, pg));
        }
        assert!(seen.len() > 990, "inputs should rarely collide");
    }
}
