//! CRUSH map data model: devices, buckets, hierarchy levels, rules.
//!
//! Mirrors the parts of Ceph's `crush_map` that the balancing problem
//! needs: a weighted tree of buckets over devices, device classes, and
//! placement rules composed of `take` / `choose` / `chooseleaf` / `emit`
//! steps. Buckets are straw2-only (the only algorithm modern Ceph uses
//! for new maps).

use std::collections::BTreeMap;

/// Device (OSD) index — non-negative, dense.
pub type OsdId = u32;

/// Node id in the hierarchy: devices are `>= 0` (the OSD id), buckets are
/// negative, exactly like Ceph's crush map encoding.
pub type NodeId = i32;

/// Device performance/media class. CRUSH rules can restrict placement to
/// one class (Ceph's `step take root class ssd`); this is how the paper's
/// clusters mix HDD/SSD/NVMe pools on one hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeviceClass {
    Hdd,
    Ssd,
    Nvme,
}

impl DeviceClass {
    pub fn as_str(&self) -> &'static str {
        match self {
            DeviceClass::Hdd => "hdd",
            DeviceClass::Ssd => "ssd",
            DeviceClass::Nvme => "nvme",
        }
    }

    pub fn parse(s: &str) -> Option<DeviceClass> {
        match s {
            "hdd" => Some(DeviceClass::Hdd),
            "ssd" => Some(DeviceClass::Ssd),
            "nvme" => Some(DeviceClass::Nvme),
            _ => None,
        }
    }

    pub const ALL: [DeviceClass; 3] = [DeviceClass::Hdd, DeviceClass::Ssd, DeviceClass::Nvme];
}

/// Hierarchy level of a bucket. Numeric values follow Ceph's default
/// type ids so comparisons ("is this bucket at/below the failure domain
/// level?") read naturally.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Osd = 0,
    Host = 1,
    Rack = 3,
    Row = 5,
    Datacenter = 8,
    Root = 10,
}

impl Level {
    /// Number of levels (for cache arrays).
    pub const COUNT: usize = 6;

    /// Dense index of this level in `[0, COUNT)`.
    pub fn rank(&self) -> usize {
        match self {
            Level::Osd => 0,
            Level::Host => 1,
            Level::Rack => 2,
            Level::Row => 3,
            Level::Datacenter => 4,
            Level::Root => 5,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Osd => "osd",
            Level::Host => "host",
            Level::Rack => "rack",
            Level::Row => "row",
            Level::Datacenter => "datacenter",
            Level::Root => "root",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "osd" => Some(Level::Osd),
            "host" => Some(Level::Host),
            "rack" => Some(Level::Rack),
            "row" => Some(Level::Row),
            "datacenter" => Some(Level::Datacenter),
            "root" => Some(Level::Root),
            _ => None,
        }
    }
}

/// A storage device (leaf of the hierarchy).
#[derive(Debug, Clone)]
pub struct Device {
    pub id: OsdId,
    /// CRUSH weight. By Ceph convention, weight = capacity in TiB.
    pub weight: f64,
    pub class: DeviceClass,
}

/// An interior node (host, rack, root, ...) aggregating children.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub id: NodeId,
    pub name: String,
    pub level: Level,
    /// Children: bucket ids (negative) or device ids (non-negative).
    pub children: Vec<NodeId>,
}

/// One step of a CRUSH rule.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Start from the named root bucket, optionally restricted to a class
    /// (implemented via class-filtered weights, equivalent to Ceph's
    /// shadow hierarchies).
    Take { root: String, class: Option<DeviceClass> },
    /// Choose `num` distinct buckets of the given level, replica-style
    /// (firstn: used for replicated pools).
    ChooseFirstN { num: i32, level: Level },
    /// Choose `num` distinct buckets of the given level and descend each
    /// to one device.
    ChooseLeafFirstN { num: i32, level: Level },
    /// Positional variant for erasure coding: failed slots stay as holes.
    ChooseIndep { num: i32, level: Level },
    /// Positional chooseleaf for EC.
    ChooseLeafIndep { num: i32, level: Level },
    /// Append the working set to the result.
    Emit,
}

/// A placement rule: an ordered program of steps. A rule may contain
/// multiple take/emit sequences (this is how hybrid rules, e.g. cluster
/// D's "primary on SSD, replicas on HDD", are expressed).
#[derive(Debug, Clone)]
pub struct Rule {
    pub id: u32,
    pub name: String,
    pub steps: Vec<Step>,
}

impl Rule {
    /// A standard replicated rule: `take root [class] / chooseleaf firstn
    /// 0 type <domain> / emit`.
    pub fn replicated(
        id: u32,
        name: &str,
        root: &str,
        class: Option<DeviceClass>,
        failure_domain: Level,
    ) -> Rule {
        Rule {
            id,
            name: name.to_string(),
            steps: vec![
                Step::Take { root: root.to_string(), class },
                Step::ChooseLeafFirstN { num: 0, level: failure_domain },
                Step::Emit,
            ],
        }
    }

    /// A standard EC rule: `take root [class] / chooseleaf indep 0 type
    /// <domain> / emit`.
    pub fn erasure(
        id: u32,
        name: &str,
        root: &str,
        class: Option<DeviceClass>,
        failure_domain: Level,
    ) -> Rule {
        Rule {
            id,
            name: name.to_string(),
            steps: vec![
                Step::Take { root: root.to_string(), class },
                Step::ChooseLeafIndep { num: 0, level: failure_domain },
                Step::Emit,
            ],
        }
    }

    /// Hybrid rule à la cluster D: first `n_first` devices from
    /// `first_class`, remaining from `second_class` (both under `root`,
    /// failure domain `domain`). Ceph expresses this as two take/emit
    /// blocks in one rule.
    pub fn hybrid(
        id: u32,
        name: &str,
        root: &str,
        first_class: DeviceClass,
        n_first: i32,
        second_class: DeviceClass,
        failure_domain: Level,
    ) -> Rule {
        Rule {
            id,
            name: name.to_string(),
            steps: vec![
                Step::Take { root: root.to_string(), class: Some(first_class) },
                Step::ChooseLeafFirstN { num: n_first, level: failure_domain },
                Step::Emit,
                Step::Take { root: root.to_string(), class: Some(second_class) },
                Step::ChooseLeafFirstN { num: -n_first, level: failure_domain },
                Step::Emit,
            ],
        }
    }
}

/// The complete CRUSH map: hierarchy + devices + rules, with per-class
/// weight caches computed at build time.
#[derive(Debug, Clone)]
pub struct CrushMap {
    /// Devices indexed by OsdId.
    pub devices: Vec<Device>,
    /// Buckets by (negative) node id.
    pub buckets: BTreeMap<NodeId, Bucket>,
    /// Rules by rule id.
    pub rules: BTreeMap<u32, Rule>,
    /// name → bucket id, for `Take`.
    pub bucket_by_name: BTreeMap<String, NodeId>,
    /// Cached: total effective weight of each node, per class and overall.
    /// `weight_cache[node]` = (total, per-class array indexed by
    /// DeviceClass order in `DeviceClass::ALL`).
    pub(crate) weight_cache: BTreeMap<NodeId, NodeWeights>,
    /// Cached: parent of each node (for subtree membership checks).
    pub(crate) parent: BTreeMap<NodeId, NodeId>,
    /// Cached: per-device ancestor at each level (indexed
    /// `[device][level_rank]`) — the balancer's failure-domain checks
    /// hit this millions of times per plan.
    pub(crate) device_ancestor: Vec<[Option<NodeId>; Level::COUNT]>,
}

#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct NodeWeights {
    pub total: f64,
    pub per_class: [f64; 3],
}

impl NodeWeights {
    pub fn for_class(&self, class: Option<DeviceClass>) -> f64 {
        match class {
            None => self.total,
            Some(c) => {
                let idx = DeviceClass::ALL.iter().position(|&x| x == c).unwrap();
                self.per_class[idx]
            }
        }
    }
}

impl CrushMap {
    /// Effective weight of a node, optionally restricted to a class.
    pub fn weight_of(&self, node: NodeId, class: Option<DeviceClass>) -> f64 {
        if node >= 0 {
            let d = &self.devices[node as usize];
            return match class {
                None => d.weight,
                Some(c) if c == d.class => d.weight,
                _ => 0.0,
            };
        }
        self.weight_cache
            .get(&node)
            .map(|w| w.for_class(class))
            .unwrap_or(0.0)
    }

    /// Does this node exist?
    pub fn contains(&self, node: NodeId) -> bool {
        if node >= 0 {
            (node as usize) < self.devices.len()
        } else {
            self.buckets.contains_key(&node)
        }
    }

    /// Node's level (devices are `Level::Osd`).
    pub fn level_of(&self, node: NodeId) -> Option<Level> {
        if node >= 0 {
            if self.contains(node) {
                Some(Level::Osd)
            } else {
                None
            }
        } else {
            self.buckets.get(&node).map(|b| b.level)
        }
    }

    /// Walk up to the ancestor bucket of the given level (e.g. the host
    /// of an OSD). Returns None if no ancestor at that level. Device
    /// lookups are O(1) via the build-time cache.
    pub fn ancestor_at(&self, node: NodeId, level: Level) -> Option<NodeId> {
        if node >= 0 {
            if let Some(cached) = self.device_ancestor.get(node as usize) {
                return cached[level.rank()];
            }
        }
        self.ancestor_at_uncached(node, level)
    }

    fn ancestor_at_uncached(&self, mut node: NodeId, level: Level) -> Option<NodeId> {
        if self.level_of(node) == Some(level) {
            return Some(node);
        }
        while let Some(&p) = self.parent.get(&node) {
            if self.level_of(p) == Some(level) {
                return Some(p);
            }
            node = p;
        }
        None
    }

    /// Is `node` inside the subtree rooted at `root`?
    pub fn in_subtree(&self, mut node: NodeId, root: NodeId) -> bool {
        if node == root {
            return true;
        }
        while let Some(&p) = self.parent.get(&node) {
            if p == root {
                return true;
            }
            node = p;
        }
        false
    }

    /// All device ids in the subtree under `node` (optionally filtered by
    /// class).
    pub fn devices_under(&self, node: NodeId, class: Option<DeviceClass>) -> Vec<OsdId> {
        let mut out = Vec::new();
        self.collect_devices(node, class, &mut out);
        out
    }

    fn collect_devices(&self, node: NodeId, class: Option<DeviceClass>, out: &mut Vec<OsdId>) {
        if node >= 0 {
            let d = &self.devices[node as usize];
            if class.is_none() || class == Some(d.class) {
                out.push(d.id);
            }
            return;
        }
        if let Some(b) = self.buckets.get(&node) {
            for &c in &b.children {
                self.collect_devices(c, class, out);
            }
        }
    }

    /// Rule lookup by id.
    pub fn rule(&self, id: u32) -> Option<&Rule> {
        self.rules.get(&id)
    }

    /// The set of device classes a rule draws from (from its Take steps).
    pub fn rule_classes(&self, rule: &Rule) -> Vec<Option<DeviceClass>> {
        rule.steps
            .iter()
            .filter_map(|s| match s {
                Step::Take { class, .. } => Some(*class),
                _ => None,
            })
            .collect()
    }

    /// All devices a rule could ever place on (union over its Take
    /// steps). This is the candidate set balancers iterate over.
    pub fn rule_devices(&self, rule: &Rule) -> Vec<OsdId> {
        let mut out = Vec::new();
        for step in &rule.steps {
            if let Step::Take { root, class } = step {
                if let Some(&node) = self.bucket_by_name.get(root) {
                    self.collect_devices(node, *class, &mut out);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}
