//! Straw2 bucket selection.
//!
//! Each child "draws a straw": `ln(u) / weight` where `u` is a
//! pseudo-random value in (0, 1] derived from `(input, item, attempt)`;
//! the child with the maximum draw wins. Straw2's key property (the reason
//! Ceph moved from straw1) is *independence*: changing one child's weight
//! only re-decides inputs that involve that child, never reshuffles
//! placements between two unchanged children.
//!
//! Ceph computes `ln` in 16.48 fixed point for bit-exact cross-platform
//! behaviour; within this repository determinism only needs to hold for
//! one binary, so we use `f64` and keep the same structure (the 16-bit
//! hash truncation matches Ceph's).

use super::hash::hash32_3;
use super::types::{CrushMap, DeviceClass, NodeId};

/// Draw value for one child. Higher wins. Zero-weight children return
/// `-inf` (never selected).
#[inline]
pub fn straw2_draw(x: u32, item: NodeId, r: u32, weight: f64) -> f64 {
    if weight <= 0.0 {
        return f64::NEG_INFINITY;
    }
    // 16 low bits of the hash, like Ceph (crush_ln input domain).
    let h = hash32_3(x, item as u32, r) & 0xffff;
    // u in (0, 1]: (h+1)/65536 avoids ln(0).
    let u = (h as f64 + 1.0) / 65536.0;
    u.ln() / weight
}

/// Select one child of `bucket` for input `x`, attempt `r`, restricted to
/// children with non-zero effective weight for `class`. Returns None if
/// the bucket is empty or has no weight in that class.
pub fn bucket_choose(
    map: &CrushMap,
    bucket: NodeId,
    x: u32,
    r: u32,
    class: Option<DeviceClass>,
) -> Option<NodeId> {
    let b = map.buckets.get(&bucket)?;
    let mut best: Option<(f64, NodeId)> = None;
    for &child in &b.children {
        let w = map.weight_of(child, class);
        let draw = straw2_draw(x, child, r, w);
        if draw == f64::NEG_INFINITY {
            continue;
        }
        match best {
            Some((bd, _)) if bd >= draw => {}
            _ => best = Some((draw, child)),
        }
    }
    best.map(|(_, c)| c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crush::builder::CrushBuilder;
    use crate::util::units::TIB;

    fn flat_map(weights_tib: &[(u64, DeviceClass)]) -> (CrushMap, NodeId) {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for &(w, c) in weights_tib {
            b.add_osd_bytes(root, w * TIB, c);
        }
        (b.build().unwrap(), -1)
    }

    #[test]
    fn selection_is_deterministic() {
        let (m, root) = flat_map(&[(4, DeviceClass::Hdd); 8].to_vec());
        for x in 0..100 {
            let a = bucket_choose(&m, root, x, 0, None);
            let b = bucket_choose(&m, root, x, 0, None);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn distribution_follows_weights() {
        // children weighted 1:2:4 should be picked roughly 1:2:4
        let (m, root) = flat_map(&[
            (1, DeviceClass::Hdd),
            (2, DeviceClass::Hdd),
            (4, DeviceClass::Hdd),
        ]);
        let n = 70_000u32;
        let mut counts = [0usize; 3];
        for x in 0..n {
            let c = bucket_choose(&m, root, x, 0, None).unwrap();
            counts[c as usize] += 1;
        }
        let total = n as f64;
        for (i, expect) in [1.0 / 7.0, 2.0 / 7.0, 4.0 / 7.0].iter().enumerate() {
            let got = counts[i] as f64 / total;
            assert!(
                (got - expect).abs() < 0.01,
                "child {i}: got {got:.4}, expected {expect:.4}"
            );
        }
    }

    #[test]
    fn class_filter_excludes_other_classes() {
        let (m, root) = flat_map(&[
            (4, DeviceClass::Hdd),
            (4, DeviceClass::Ssd),
            (4, DeviceClass::Hdd),
        ]);
        for x in 0..500 {
            let c = bucket_choose(&m, root, x, 0, Some(DeviceClass::Ssd)).unwrap();
            assert_eq!(c, 1, "only the SSD child may be chosen");
        }
        for x in 0..500 {
            let c = bucket_choose(&m, root, x, 0, Some(DeviceClass::Hdd)).unwrap();
            assert!(c == 0 || c == 2);
        }
    }

    #[test]
    fn no_weight_returns_none() {
        let (m, root) = flat_map(&[(4, DeviceClass::Hdd)]);
        assert_eq!(bucket_choose(&m, root, 1, 0, Some(DeviceClass::Nvme)), None);
    }

    #[test]
    fn straw2_stability_under_weight_change() {
        // The defining straw2 property: doubling child 2's weight must not
        // move any input that was previously mapped to child 0 onto child 1
        // (or vice versa) — movement only flows *toward* the changed child.
        let (m1, root) = flat_map(&[
            (4, DeviceClass::Hdd),
            (4, DeviceClass::Hdd),
            (4, DeviceClass::Hdd),
        ]);
        let (mut m2, _) = flat_map(&[
            (4, DeviceClass::Hdd),
            (4, DeviceClass::Hdd),
            (4, DeviceClass::Hdd),
        ]);
        m2.devices[2].weight *= 2.0;
        m2.recompute_weights();
        for x in 0..20_000 {
            let before = bucket_choose(&m1, root, x, 0, None).unwrap();
            let after = bucket_choose(&m2, root, x, 0, None).unwrap();
            if before != after {
                assert_eq!(after, 2, "input {x} moved to {after}, not to the grown child");
            }
        }
    }

    #[test]
    fn attempts_decorrelate() {
        let (m, root) = flat_map(&[(4, DeviceClass::Hdd); 16].to_vec());
        // different r should give a different child often enough
        let mut moved = 0;
        for x in 0..1000 {
            let a = bucket_choose(&m, root, x, 0, None).unwrap();
            let b = bucket_choose(&m, root, x, 1, None).unwrap();
            if a != b {
                moved += 1;
            }
        }
        assert!(moved > 800, "r must decorrelate selections, moved={moved}");
    }
}
