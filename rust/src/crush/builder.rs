//! Construction and validation of CRUSH maps.

use std::collections::BTreeMap;

use super::types::{
    Bucket, CrushMap, Device, DeviceClass, Level, NodeId, NodeWeights, OsdId, Rule,
};
use crate::util::units::TIB;

/// Errors detected while building or validating a map.
#[derive(Debug, PartialEq)]
pub enum BuildError {
    /// Two buckets share a name.
    DuplicateName(String),
    /// A bucket references a parent id that was never created.
    UnknownParent(NodeId),
    /// A bucket lists a child that does not exist.
    DanglingChild {
        /// The bucket listing the child.
        parent: NodeId,
        /// The nonexistent child id.
        child: NodeId,
    },
    /// A node is claimed by more than one parent.
    MultipleParents(NodeId),
    /// The hierarchy is not a tree.
    Cycle(NodeId),
    /// A child's level is not strictly below its parent's.
    LevelInversion {
        /// The parent bucket.
        parent: NodeId,
        /// Its level.
        parent_level: Level,
        /// The offending child.
        child: NodeId,
        /// The child's level.
        child_level: Level,
    },
    /// A rule's `take` step names a bucket that does not exist.
    UnknownRoot {
        /// The rule id.
        rule: u32,
        /// The unknown bucket name.
        root: String,
    },
    /// Two rules share an id.
    DuplicateRule(u32),
}

impl std::fmt::Display for BuildError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BuildError::DuplicateName(name) => write!(f, "duplicate bucket name '{name}'"),
            BuildError::UnknownParent(id) => write!(f, "unknown parent bucket id {id}"),
            BuildError::DanglingChild { parent, child } => {
                write!(f, "child {child} of bucket {parent} does not exist")
            }
            BuildError::MultipleParents(id) => write!(f, "node {id} has multiple parents"),
            BuildError::Cycle(id) => {
                write!(f, "hierarchy contains a cycle involving bucket {id}")
            }
            BuildError::LevelInversion { parent, parent_level, child, child_level } => write!(
                f,
                "bucket {child} of level {child_level:?} under {parent} of level {parent_level:?}"
            ),
            BuildError::UnknownRoot { rule, root } => {
                write!(f, "rule {rule} takes unknown bucket '{root}'")
            }
            BuildError::DuplicateRule(id) => write!(f, "duplicate rule id {id}"),
        }
    }
}

impl std::error::Error for BuildError {}

/// Incremental builder. Typical use:
///
/// ```
/// use equilibrium::crush::builder::CrushBuilder;
/// use equilibrium::crush::types::{DeviceClass, Level, Rule};
///
/// let mut b = CrushBuilder::new();
/// let root = b.add_root("default");
/// let h1 = b.add_bucket("host1", Level::Host, root);
/// let h2 = b.add_bucket("host2", Level::Host, root);
/// b.add_osd_bytes(h1, 4 << 40, DeviceClass::Hdd);
/// b.add_osd_bytes(h2, 4 << 40, DeviceClass::Hdd);
/// b.add_rule(Rule::replicated(0, "repl", "default", None, Level::Host));
/// let map = b.build().unwrap();
/// assert_eq!(map.devices.len(), 2);
/// ```
#[derive(Debug, Default)]
pub struct CrushBuilder {
    devices: Vec<Device>,
    buckets: BTreeMap<NodeId, Bucket>,
    rules: Vec<Rule>,
    next_bucket_id: NodeId,
}

impl CrushBuilder {
    pub fn new() -> Self {
        CrushBuilder { devices: Vec::new(), buckets: BTreeMap::new(), rules: Vec::new(), next_bucket_id: -1 }
    }

    /// Add a root-level bucket.
    pub fn add_root(&mut self, name: &str) -> NodeId {
        self.add_orphan_bucket(name, Level::Root)
    }

    /// Add a bucket without a parent (roots, or attach later).
    pub fn add_orphan_bucket(&mut self, name: &str, level: Level) -> NodeId {
        let id = self.next_bucket_id;
        self.next_bucket_id -= 1;
        self.buckets.insert(
            id,
            Bucket { id, name: name.to_string(), level, children: Vec::new() },
        );
        id
    }

    /// Add a bucket under `parent`.
    pub fn add_bucket(&mut self, name: &str, level: Level, parent: NodeId) -> NodeId {
        let id = self.add_orphan_bucket(name, level);
        if let Some(p) = self.buckets.get_mut(&parent) {
            p.children.push(id);
        } else {
            // keep the dangling reference; build() will report it
            self.buckets.get_mut(&id).unwrap().children.push(parent);
        }
        id
    }

    /// Add a device with an explicit CRUSH weight.
    pub fn add_osd(&mut self, parent: NodeId, weight: f64, class: DeviceClass) -> OsdId {
        let id = self.devices.len() as OsdId;
        self.devices.push(Device { id, weight, class });
        if let Some(p) = self.buckets.get_mut(&parent) {
            p.children.push(id as NodeId);
        }
        id
    }

    /// Add a device sized in bytes (weight = TiB, Ceph convention).
    pub fn add_osd_bytes(&mut self, parent: NodeId, size_bytes: u64, class: DeviceClass) -> OsdId {
        self.add_osd(parent, size_bytes as f64 / TIB as f64, class)
    }

    pub fn add_rule(&mut self, rule: Rule) {
        self.rules.push(rule);
    }

    /// Validate and produce the finished map (computes weight caches and
    /// parent links).
    pub fn build(self) -> Result<CrushMap, BuildError> {
        from_parts(self.devices, self.buckets, self.rules)
    }
}

/// Assemble a validated map from raw parts (used by the builder and by
/// the dump loader, which must preserve bucket ids exactly — straw2 draws
/// hash on node ids, so ids are part of placement determinism).
pub fn from_parts(
    devices: Vec<Device>,
    buckets: BTreeMap<NodeId, Bucket>,
    rules: Vec<Rule>,
) -> Result<CrushMap, BuildError> {
    PartsView { devices, buckets, rules }.finish()
}

struct PartsView {
    devices: Vec<Device>,
    buckets: BTreeMap<NodeId, Bucket>,
    rules: Vec<Rule>,
}

impl PartsView {
    fn finish(self) -> Result<CrushMap, BuildError> {
        let mut bucket_by_name = BTreeMap::new();
        for b in self.buckets.values() {
            if bucket_by_name.insert(b.name.clone(), b.id).is_some() {
                return Err(BuildError::DuplicateName(b.name.clone()));
            }
        }

        // parent links + structural validation
        let mut parent: BTreeMap<NodeId, NodeId> = BTreeMap::new();
        for b in self.buckets.values() {
            for &c in &b.children {
                let exists = if c >= 0 {
                    (c as usize) < self.devices.len()
                } else {
                    self.buckets.contains_key(&c)
                };
                if !exists {
                    return Err(BuildError::DanglingChild { parent: b.id, child: c });
                }
                if parent.insert(c, b.id).is_some() {
                    return Err(BuildError::MultipleParents(c));
                }
                if c < 0 {
                    let cl = self.buckets[&c].level;
                    if cl >= b.level {
                        return Err(BuildError::LevelInversion {
                            parent: b.id,
                            parent_level: b.level,
                            child: c,
                            child_level: cl,
                        });
                    }
                }
            }
        }

        // cycle check: follow parents from every bucket; because levels
        // strictly decrease child-ward this cannot loop, but a bucket
        // reachable from itself via children (malformed insert) is caught
        // by walking with a step bound.
        for &id in self.buckets.keys() {
            let mut cur = id;
            let mut steps = 0;
            while let Some(&p) = parent.get(&cur) {
                cur = p;
                steps += 1;
                if steps > self.buckets.len() {
                    return Err(BuildError::Cycle(id));
                }
            }
        }

        // rule validation
        let mut rules = BTreeMap::new();
        for r in self.rules {
            for step in &r.steps {
                if let super::types::Step::Take { root, .. } = step {
                    if !bucket_by_name.contains_key(root) {
                        return Err(BuildError::UnknownRoot { rule: r.id, root: root.clone() });
                    }
                }
            }
            if rules.insert(r.id, r).is_some() {
                let id = *rules.keys().last().unwrap();
                return Err(BuildError::DuplicateRule(id));
            }
        }

        let mut map = CrushMap {
            devices: self.devices,
            buckets: self.buckets,
            rules,
            bucket_by_name,
            weight_cache: BTreeMap::new(),
            parent,
            device_ancestor: Vec::new(),
        };
        map.recompute_weights();
        map.rebuild_ancestor_cache();
        Ok(map)
    }
}

impl CrushMap {
    /// Recompute the per-node (total, per-class) weight caches. Called by
    /// the builder; callers that mutate device weights (e.g. failure
    /// injection in tests) must call this again.
    pub fn recompute_weights(&mut self) {
        let ids: Vec<NodeId> = self.buckets.keys().copied().collect();
        let mut cache: BTreeMap<NodeId, NodeWeights> = BTreeMap::new();
        // iterate until fixpoint-free: compute via DFS with memo
        for id in ids {
            self.node_weight_memo(id, &mut cache);
        }
        self.weight_cache = cache;
    }

    /// Rebuild the per-device ancestor cache (after structural changes).
    pub fn rebuild_ancestor_cache(&mut self) {
        use super::types::Level;
        let mut cache = Vec::with_capacity(self.devices.len());
        for d in 0..self.devices.len() as NodeId {
            let mut row = [None; Level::COUNT];
            for level in [Level::Osd, Level::Host, Level::Rack, Level::Row, Level::Datacenter, Level::Root]
            {
                // compute with the walking path (cache not consulted for
                // an out-of-range index, but be explicit):
                row[level.rank()] = if level == Level::Osd {
                    Some(d)
                } else {
                    self.walk_ancestor(d, level)
                };
            }
            cache.push(row);
        }
        self.device_ancestor = cache;
    }

    fn walk_ancestor(&self, mut node: NodeId, level: super::types::Level) -> Option<NodeId> {
        while let Some(&p) = self.parent.get(&node) {
            if self.level_of(p) == Some(level) {
                return Some(p);
            }
            node = p;
        }
        None
    }

    fn node_weight_memo(&self, node: NodeId, cache: &mut BTreeMap<NodeId, NodeWeights>) -> NodeWeights {
        if node >= 0 {
            let d = &self.devices[node as usize];
            let mut w = NodeWeights::default();
            w.total = d.weight;
            let idx = DeviceClass::ALL.iter().position(|&x| x == d.class).unwrap();
            w.per_class[idx] = d.weight;
            return w;
        }
        if let Some(w) = cache.get(&node) {
            return *w;
        }
        let children = self.buckets.get(&node).map(|b| b.children.clone()).unwrap_or_default();
        let mut acc = NodeWeights::default();
        for c in children {
            let w = self.node_weight_memo(c, cache);
            acc.total += w.total;
            for i in 0..3 {
                acc.per_class[i] += w.per_class[i];
            }
        }
        cache.insert(node, acc);
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::TIB;

    fn two_host_map() -> CrushMap {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        let h1 = b.add_bucket("host1", Level::Host, root);
        let h2 = b.add_bucket("host2", Level::Host, root);
        b.add_osd_bytes(h1, 4 * TIB, DeviceClass::Hdd);
        b.add_osd_bytes(h1, 4 * TIB, DeviceClass::Ssd);
        b.add_osd_bytes(h2, 8 * TIB, DeviceClass::Hdd);
        b.add_rule(Rule::replicated(0, "repl", "default", None, Level::Host));
        b.build().unwrap()
    }

    #[test]
    fn weights_aggregate_up_the_tree() {
        let m = two_host_map();
        let root = m.bucket_by_name["default"];
        assert!((m.weight_of(root, None) - 16.0).abs() < 1e-9);
        assert!((m.weight_of(root, Some(DeviceClass::Hdd)) - 12.0).abs() < 1e-9);
        assert!((m.weight_of(root, Some(DeviceClass::Ssd)) - 4.0).abs() < 1e-9);
        assert!((m.weight_of(root, Some(DeviceClass::Nvme))).abs() < 1e-9);
    }

    #[test]
    fn parents_and_ancestors() {
        let m = two_host_map();
        let h1 = m.bucket_by_name["host1"];
        let root = m.bucket_by_name["default"];
        assert_eq!(m.ancestor_at(0, Level::Host), Some(h1));
        assert_eq!(m.ancestor_at(0, Level::Root), Some(root));
        assert!(m.in_subtree(0, h1));
        assert!(m.in_subtree(0, root));
        assert!(!m.in_subtree(2, h1));
    }

    #[test]
    fn devices_under_with_class_filter() {
        let m = two_host_map();
        let root = m.bucket_by_name["default"];
        assert_eq!(m.devices_under(root, None), vec![0, 1, 2]);
        assert_eq!(m.devices_under(root, Some(DeviceClass::Hdd)), vec![0, 2]);
        assert_eq!(m.devices_under(root, Some(DeviceClass::Ssd)), vec![1]);
    }

    #[test]
    fn rejects_duplicate_names() {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        b.add_bucket("h", Level::Host, root);
        b.add_bucket("h", Level::Host, root);
        assert!(matches!(b.build(), Err(BuildError::DuplicateName(_))));
    }

    #[test]
    fn rejects_level_inversion() {
        let mut b = CrushBuilder::new();
        let host = b.add_orphan_bucket("h", Level::Host);
        let _root_under_host = b.add_bucket("r", Level::Root, host);
        assert!(matches!(b.build(), Err(BuildError::LevelInversion { .. })));
    }

    #[test]
    fn rejects_unknown_rule_root() {
        let mut b = CrushBuilder::new();
        b.add_root("default");
        b.add_rule(Rule::replicated(0, "r", "nonexistent", None, Level::Host));
        assert!(matches!(b.build(), Err(BuildError::UnknownRoot { .. })));
    }

    #[test]
    fn rule_devices_unions_takes() {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        let h1 = b.add_bucket("host1", Level::Host, root);
        b.add_osd_bytes(h1, TIB, DeviceClass::Ssd);
        b.add_osd_bytes(h1, TIB, DeviceClass::Hdd);
        b.add_osd_bytes(h1, TIB, DeviceClass::Hdd);
        b.add_rule(Rule::hybrid(
            7,
            "hyb",
            "default",
            DeviceClass::Ssd,
            1,
            DeviceClass::Hdd,
            Level::Osd,
        ));
        let m = b.build().unwrap();
        let r = m.rule(7).unwrap();
        assert_eq!(m.rule_devices(r), vec![0, 1, 2]);
        assert_eq!(
            m.rule_classes(r),
            vec![Some(DeviceClass::Ssd), Some(DeviceClass::Hdd)]
        );
    }
}
