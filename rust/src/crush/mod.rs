//! From-scratch CRUSH implementation: the placement substrate the
//! balancers operate against.
//!
//! CRUSH ("Controlled Replication Under Scalable Hashing", Weil et al.
//! 2006) maps a placement-group input to an ordered device set through a
//! weighted hierarchy, pseudo-randomly but deterministically, honouring
//! failure-domain and device-class constraints. The balancing problem
//! exists because this distribution is only statistically — not exactly —
//! proportional to weights (paper §2.2).

pub mod builder;
pub mod hash;
pub mod map;
pub mod straw2;
pub mod text;
pub mod types;

pub use builder::{from_parts, BuildError, CrushBuilder};
pub use map::{map_rule, pg_input, Mapping, TOTAL_TRIES};
pub use types::{CrushMap, Device, DeviceClass, Level, NodeId, OsdId, Rule, Step};
