//! The rjenkins1 hash family used by CRUSH.
//!
//! This is a faithful port of Ceph's `crush/hash.c` (`crush_hash32_*`,
//! algorithm CRUSH_HASH_RJENKINS1). Placement decisions must be a pure
//! function of (input key, item id, attempt), stable across runs and
//! machines — a keyed integer hash, not a general-purpose one.

const CRUSH_HASH_SEED: u32 = 1315423911;

/// Robert Jenkins' 96-bit mix function (one round).
#[inline]
fn hashmix(mut a: u32, mut b: u32, mut c: u32) -> (u32, u32, u32) {
    a = a.wrapping_sub(b);
    a = a.wrapping_sub(c);
    a ^= c >> 13;
    b = b.wrapping_sub(c);
    b = b.wrapping_sub(a);
    b ^= a << 8;
    c = c.wrapping_sub(a);
    c = c.wrapping_sub(b);
    c ^= b >> 13;
    a = a.wrapping_sub(b);
    a = a.wrapping_sub(c);
    a ^= c >> 12;
    b = b.wrapping_sub(c);
    b = b.wrapping_sub(a);
    b ^= a << 16;
    c = c.wrapping_sub(a);
    c = c.wrapping_sub(b);
    c ^= b >> 5;
    a = a.wrapping_sub(b);
    a = a.wrapping_sub(c);
    a ^= c >> 3;
    b = b.wrapping_sub(c);
    b = b.wrapping_sub(a);
    b ^= a << 10;
    c = c.wrapping_sub(a);
    c = c.wrapping_sub(b);
    c ^= b >> 15;
    (a, b, c)
}

/// `crush_hash32_rjenkins1(a)`.
pub fn hash32_1(a: u32) -> u32 {
    let mut hash = CRUSH_HASH_SEED ^ a;
    let b = a;
    let x = 231232u32;
    let y = 1232u32;
    let (_, _, h) = hashmix(b, x, hash);
    hash = h;
    let (_, _, h) = hashmix(y, a, hash);
    h
}

/// `crush_hash32_rjenkins1_2(a, b)`.
pub fn hash32_2(a: u32, b: u32) -> u32 {
    let mut hash = CRUSH_HASH_SEED ^ a ^ b;
    let x = 231232u32;
    let y = 1232u32;
    let (a2, _, h) = hashmix(a, b, hash);
    hash = h;
    let (_, _, h) = hashmix(x, a2, hash);
    hash = h;
    let (_, _, h) = hashmix(b, y, hash);
    h
}

/// `crush_hash32_rjenkins1_3(a, b, c)`.
pub fn hash32_3(a: u32, b: u32, c: u32) -> u32 {
    let mut hash = CRUSH_HASH_SEED ^ a ^ b ^ c;
    let x = 231232u32;
    let y = 1232u32;
    let (a2, _, h) = hashmix(a, b, hash);
    hash = h;
    let (_, _, h) = hashmix(c, x, hash);
    hash = h;
    let (_, a3, h) = hashmix(y, a2, hash);
    hash = h;
    let (_, _, h) = hashmix(b, x, hash);
    hash = h;
    let (_, _, h) = hashmix(y, c, hash);
    let _ = a3;
    h
}

/// `crush_hash32_rjenkins1_4(a, b, c, d)` — used for PG → placement seed.
pub fn hash32_4(a: u32, b: u32, c: u32, d: u32) -> u32 {
    let mut hash = CRUSH_HASH_SEED ^ a ^ b ^ c ^ d;
    let x = 231232u32;
    let y = 1232u32;
    let (a2, _, h) = hashmix(a, b, hash);
    hash = h;
    let (_, _, h) = hashmix(c, d, hash);
    hash = h;
    let (a3, _, h) = hashmix(a2, x, hash);
    hash = h;
    let (_, _, h) = hashmix(y, a3, hash);
    hash = h;
    let (_, _, h) = hashmix(b, x, hash);
    hash = h;
    let (_, _, h) = hashmix(y, c, hash);
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(hash32_1(12345), hash32_1(12345));
        assert_eq!(hash32_2(1, 2), hash32_2(1, 2));
        assert_eq!(hash32_3(1, 2, 3), hash32_3(1, 2, 3));
        assert_eq!(hash32_4(1, 2, 3, 4), hash32_4(1, 2, 3, 4));
    }

    #[test]
    fn argument_order_matters() {
        assert_ne!(hash32_2(1, 2), hash32_2(2, 1));
        assert_ne!(hash32_3(1, 2, 3), hash32_3(3, 2, 1));
    }

    #[test]
    fn small_input_changes_avalanche() {
        // flipping one input bit should flip roughly half the output bits
        let mut total = 0u32;
        let n = 256;
        for i in 0..n {
            let a = hash32_3(i, 7, 9);
            let b = hash32_3(i ^ 1, 7, 9);
            total += (a ^ b).count_ones();
        }
        let avg = total as f64 / n as f64;
        assert!((avg - 16.0).abs() < 3.0, "avalanche avg {avg}");
    }

    #[test]
    fn output_is_roughly_uniform_in_low_16_bits() {
        // straw2 consumes hash & 0xffff; check bucket occupancy
        let mut counts = [0u32; 16];
        let n = 65536u32;
        for x in 0..n {
            let h = hash32_3(x, 42, 3) & 0xffff;
            counts[(h >> 12) as usize] += 1;
        }
        let expect = n / 16;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as i64 - expect as i64).abs() < (expect / 5) as i64,
                "bucket {i}: {c} vs {expect}"
            );
        }
    }
}
