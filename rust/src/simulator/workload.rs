//! Client write workload models for the daemon and robustness tests.
//!
//! The paper's §2.2 observation — "pools grow and shrink independently" —
//! is the root cause of drift away from balance. These models generate
//! that drift: uniform object writes, Zipf-skewed pool popularity, and
//! hotspot bursts.

use crate::cluster::{ClusterState, PgId, PoolKind};
use crate::util::rng::Rng;

/// How client writes are distributed across pools and PGs.
#[derive(Debug, Clone)]
pub enum WorkloadModel {
    /// Every user pool receives traffic proportional to its PG count;
    /// objects hash uniformly into PGs (Ceph's steady state).
    Uniform,
    /// Pool popularity follows a Zipf distribution with the given
    /// exponent (>=0); 1.0 is classic web-like skew.
    ZipfPools { exponent: f64 },
    /// One pool takes `fraction` of all writes (ingest burst); the rest
    /// spreads uniformly.
    Hotspot { pool: u32, fraction: f64 },
}

/// A write workload bound to a model and a seeded RNG.
#[derive(Debug)]
pub struct Workload {
    pub model: WorkloadModel,
    rng: Rng,
}

impl Workload {
    pub fn new(model: WorkloadModel, seed: u64) -> Workload {
        Workload { model, rng: Rng::new(seed) }
    }

    /// Apply `user_bytes` of client writes to the cluster. Returns the
    /// bytes actually applied (rounding can drop a remainder).
    pub fn write(&mut self, state: &mut ClusterState, user_bytes: u64) -> u64 {
        let mut pools: Vec<(u32, u32, f64)> = state
            .pools
            .values()
            .filter(|p| p.kind == PoolKind::UserData)
            .map(|p| (p.id, p.pg_count, p.redundancy.shard_fraction()))
            .collect();
        // BTreeMap iteration happens to be id-ordered, but the Zipf rank
        // assignment below must not depend on the map's iteration order —
        // sort explicitly so rank i always goes to the i-th lowest pool id
        pools.sort_by_key(|&(id, _, _)| id);
        if pools.is_empty() || user_bytes == 0 {
            return 0;
        }

        // per-pool byte shares according to the model
        let weights: Vec<f64> = match &self.model {
            WorkloadModel::Uniform => pools.iter().map(|&(_, c, _)| c as f64).collect(),
            WorkloadModel::ZipfPools { exponent } => {
                // pools are sorted by id above, so rank follows pool id
                (1..=pools.len()).map(|rank| 1.0 / (rank as f64).powf(*exponent)).collect()
            }
            WorkloadModel::Hotspot { pool, fraction } => pools
                .iter()
                .map(|&(id, c, _)| {
                    if id == *pool {
                        // the hotspot share plus its fair share of the rest
                        fraction * 1e9 // dominating weight
                    } else {
                        c as f64
                    }
                })
                .collect(),
        };
        let wsum: f64 = weights.iter().sum();
        if wsum <= 0.0 {
            return 0;
        }

        let mut written = 0u64;
        for (i, &(pool_id, _, _)) in pools.iter().enumerate() {
            let pool_bytes = (user_bytes as f64 * weights[i] / wsum) as u64;
            written += write_pool(state, pool_id, pool_bytes, &mut self.rng);
        }
        written
    }
}

/// One PG-hit model for both directions: spread `pool_bytes` over up to
/// 64 random PGs with an equal share each (objects hash uniformly into
/// PGs), applying `grow` (writes) or shrink (deletions) per hit.
fn touch_pool(
    state: &mut ClusterState,
    pool_id: u32,
    pool_bytes: u64,
    rng: &mut Rng,
    grow: bool,
) -> u64 {
    let Some(pool) = state.pools.get(&pool_id) else { return 0 };
    let (pg_count, shard_fraction) = (pool.pg_count, pool.redundancy.shard_fraction());
    if pool_bytes == 0 || pg_count == 0 {
        return 0;
    }
    // spread over up to 64 random PGs per pool per round
    let hits = (pg_count as usize).min(64);
    let per_pg = pool_bytes / hits as u64;
    if per_pg == 0 {
        return 0;
    }
    let mut applied = 0u64;
    for _ in 0..hits {
        let idx = rng.below(pg_count as u64) as u32;
        let per_shard = (per_pg as f64 * shard_fraction).round() as u64;
        if per_shard == 0 {
            continue;
        }
        let pg = PgId::new(pool_id, idx);
        let ok = if grow {
            state.grow_pg(pg, per_shard).is_ok()
        } else {
            state.shrink_pg_by(pg, per_shard).is_ok()
        };
        if ok {
            applied += per_pg;
        }
    }
    applied
}

/// Apply `pool_bytes` of user writes to one pool: up to 64 random PGs
/// are hit with an equal share (objects hash uniformly into PGs).
/// Returns the user bytes actually applied. Shared by
/// [`Workload::write`] and the scenario engine's `GrowPool` event.
pub fn write_pool(state: &mut ClusterState, pool_id: u32, pool_bytes: u64, rng: &mut Rng) -> u64 {
    touch_pool(state, pool_id, pool_bytes, rng, true)
}

/// Delete `pool_bytes` of user data from one pool: up to 64 random PGs
/// shed an equal share (clamped at empty). Returns the user bytes
/// requested for deletion from existing PGs (actual raw reduction can be
/// smaller when a PG runs empty). Used by the scenario engine's
/// `ShrinkPool` event.
pub fn delete_from_pool(
    state: &mut ClusterState,
    pool_id: u32,
    pool_bytes: u64,
    rng: &mut Rng,
) -> u64 {
    touch_pool(state, pool_id, pool_bytes, rng, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::clusters;
    use crate::util::units::GIB;

    #[test]
    fn uniform_spreads_proportionally() {
        let mut s = clusters::demo(31);
        let before = s.total_used();
        let mut w = Workload::new(WorkloadModel::Uniform, 1);
        let written = w.write(&mut s, 64 * GIB);
        assert!(written > 0);
        assert!(s.total_used() > before);
        assert!(s.verify().is_empty());
    }

    #[test]
    fn hotspot_targets_the_pool() {
        use crate::cluster::{ClusterState, Pool};
        use crate::crush::{CrushBuilder, DeviceClass, Level, Rule};
        use crate::util::units::TIB;
        // two user pools so the hotspot has something to dominate
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..4 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            b.add_osd_bytes(host, 8 * TIB, DeviceClass::Hdd);
        }
        b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
        let mut s = ClusterState::build(
            b.build().unwrap(),
            vec![Pool::replicated(1, "hot", 3, 32, 0), Pool::replicated(2, "cold", 3, 32, 0)],
            |_, _| GIB,
        );

        let pool_used = |s: &ClusterState, pool: u32| -> u64 {
            s.pgs_of_pool(pool)
                .map(|p| p.shard_bytes() * p.devices().count() as u64)
                .sum()
        };
        let (hot_before, cold_before) = (pool_used(&s, 1), pool_used(&s, 2));
        let mut w = Workload::new(WorkloadModel::Hotspot { pool: 1, fraction: 0.95 }, 2);
        w.write(&mut s, 64 * GIB);
        let delta_hot = pool_used(&s, 1) - hot_before;
        let delta_cold = pool_used(&s, 2) - cold_before;
        assert!(
            delta_hot as f64 >= 0.9 * (delta_hot + delta_cold) as f64,
            "hotspot got {delta_hot}, cold got {delta_cold}"
        );
        assert!(s.verify().is_empty());
    }

    #[test]
    fn zipf_skews_toward_low_ids() {
        let mut s = clusters::demo(33);
        // add a second user pool id=2? demo has pool 2 = metadata, so
        // just validate determinism + accounting on the single user pool
        let mut w1 = Workload::new(WorkloadModel::ZipfPools { exponent: 1.2 }, 5);
        let mut w2 = Workload::new(WorkloadModel::ZipfPools { exponent: 1.2 }, 5);
        let mut s2 = s.clone();
        let a = w1.write(&mut s, 16 * GIB);
        let b = w2.write(&mut s2, 16 * GIB);
        assert_eq!(a, b, "same seed, same writes");
        assert_eq!(s.total_used(), s2.total_used());
    }

    #[test]
    fn zero_bytes_is_noop() {
        let mut s = clusters::demo(34);
        let before = s.total_used();
        let mut w = Workload::new(WorkloadModel::Uniform, 9);
        assert_eq!(w.write(&mut s, 0), 0);
        assert_eq!(s.total_used(), before);
    }
}
