//! Cluster simulator: drives balancers against cluster states, applies
//! their movements, and records the measurements behind the paper's
//! evaluation (free space, utilization variance, movement amount,
//! calculation time).

pub mod apply;
pub mod workload;
pub mod timeseries;

pub use apply::{compare, simulate, SimOptions, SimResult};
pub use timeseries::{Sample, TimeSeries};
pub use workload::{delete_from_pool, write_pool, Workload, WorkloadModel};
