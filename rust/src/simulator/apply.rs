//! Drive a balancer against a simulated cluster and record the paper's
//! measurements (§3.2: "their effects were applied in a simulated Ceph
//! cluster in order to measure the movement amount, to predict the
//! resulting free space, and to track OSD utilizations and their
//! variance").
//!
//! Since the scenario-engine refactor this is a thin adapter: `simulate`
//! is the pure-balancing scenario — one `BalanceRound` event executed by
//! [`crate::scenario::ScenarioEngine`] in planning-only mode (no
//! executor, virtual clock frozen at zero). The emitted movement
//! sequence is identical to the historical select/apply loop; the
//! golden-trace suite pins that equivalence.

use crate::balancer::Balancer;
use crate::cluster::{ClusterState, Movement};
use crate::plan::{PlanConfig, PlanReport};
use crate::scenario::{ScenarioConfig, ScenarioEngine, ScenarioEvent};

use super::timeseries::TimeSeries;

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Hard movement cap (the paper's osdmaptool invocation used 10 000).
    pub max_moves: usize,
    /// Record a sample every `sample_every` moves (1 = every move, as the
    /// figures need; larger values keep huge runs cheap). 0 is clamped
    /// to 1.
    pub sample_every: usize,
    /// Movement plan pipeline (RFC 0003). With `optimize` on, the
    /// result additionally carries the minimal equivalent plan in
    /// [`SimResult::optimized`]; the recorded `movements` stay the raw
    /// planner output. Off by default.
    pub plan: PlanConfig,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { max_moves: 10_000, sample_every: 1, plan: PlanConfig::default() }
    }
}

/// Result of one balancer run.
#[derive(Debug)]
pub struct SimResult {
    /// Balancer name.
    pub balancer: String,
    /// Movements in plan order.
    pub movements: Vec<Movement>,
    /// Per-move series (first sample = initial state).
    pub series: TimeSeries,
    /// True if the balancer converged (returned None) rather than
    /// hitting the move cap.
    pub converged: bool,
    /// Total balancer compute time, seconds.
    pub total_calc_seconds: f64,
    /// The minimal equivalent plan, when [`SimOptions::plan`] enabled
    /// the optimizer (`None` otherwise).
    pub optimized: Option<Vec<Movement>>,
    /// Aggregated pipeline stats (zeros when the pipeline is off).
    pub plan: PlanReport,
}

impl SimResult {
    pub fn total_moved_bytes(&self) -> u64 {
        self.movements.iter().map(|m| m.bytes).sum()
    }
}

/// Run `balancer` on `state` until convergence or the cap, timing each
/// movement computation (Figure 6's channel).
///
/// Thin scenario adapter: a single `BalanceRound` under a planning-only
/// engine. Sampling every `sample_every` moves falls out of the engine's
/// chunked `propose_batch` drive (chunk = stride), which the golden
/// suite pins to the exact per-move sequence.
pub fn simulate(balancer: &mut dyn Balancer, state: &mut ClusterState, opts: &SimOptions) -> SimResult {
    let name = balancer.name().to_string();
    let mut cfg = ScenarioConfig::planning_only(opts.sample_every.max(1));
    cfg.plan = opts.plan.clone();
    let mut engine = ScenarioEngine::new(state, Some(balancer), cfg, 0);
    let round = engine
        .apply(&ScenarioEvent::BalanceRound { max_moves: opts.max_moves })
        .expect("a balancer is attached, so BalanceRound cannot fail");
    let out = engine.finish();

    SimResult {
        balancer: name,
        movements: out.movements,
        series: out.series,
        converged: round.converged,
        total_calc_seconds: out.total_calc_seconds,
        optimized: out.executed.filter(|_| opts.plan.optimize),
        plan: out.plan,
    }
}

/// Compare both balancers from the same initial state (the paper's
/// experimental protocol: "Both balancers start with the same cluster
/// state"). Returns (mgr result, equilibrium result).
pub fn compare<FA, FB>(
    initial: &ClusterState,
    mut make_baseline: FA,
    mut make_equilibrium: FB,
    opts: &SimOptions,
) -> (SimResult, SimResult)
where
    FA: FnMut() -> Box<dyn Balancer>,
    FB: FnMut() -> Box<dyn Balancer>,
{
    let mut state_a = initial.clone();
    let mut bal_a = make_baseline();
    let res_a = simulate(bal_a.as_mut(), &mut state_a, opts);

    let mut state_b = initial.clone();
    let mut bal_b = make_equilibrium();
    let res_b = simulate(bal_b.as_mut(), &mut state_b, opts);

    (res_a, res_b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::{Equilibrium, MgrBalancer};
    use crate::cluster::{ClusterState, Pool};
    use crate::crush::{CrushBuilder, DeviceClass, Level, Rule};
    use crate::util::units::{GIB, TIB};

    fn cluster() -> ClusterState {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..6 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            let size = if h < 2 { 8 * TIB } else { 4 * TIB };
            b.add_osd_bytes(host, size, DeviceClass::Hdd);
        }
        b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
        ClusterState::build(
            b.build().unwrap(),
            vec![Pool::replicated(1, "p", 3, 64, 0)],
            |_, i| (8 + (i % 9) as u64) * GIB,
        )
    }

    #[test]
    fn simulate_records_per_move_samples() {
        let mut state = cluster();
        let mut bal = Equilibrium::default();
        let res = simulate(&mut bal, &mut state, &SimOptions::default());
        assert!(res.converged);
        assert!(!res.movements.is_empty());
        // samples: initial + one per move
        assert_eq!(res.series.samples.len(), res.movements.len() + 1);
        // variance decreases monotonically for Equilibrium
        let vars: Vec<f64> = res.series.samples.iter().map(|s| s.variance).collect();
        for w in vars.windows(2) {
            assert!(w[1] <= w[0] + 1e-15, "variance must not increase: {w:?}");
        }
        assert_eq!(res.total_moved_bytes(), res.movements.iter().map(|m| m.bytes).sum());
    }

    #[test]
    fn move_cap_is_respected_and_flagged() {
        let mut state = cluster();
        let mut bal = Equilibrium::default();
        let res = simulate(&mut bal, &mut state, &SimOptions { max_moves: 2, sample_every: 1, ..SimOptions::default() });
        assert!(res.movements.len() <= 2);
        if res.movements.len() == 2 {
            assert!(!res.converged);
        }
    }

    /// With the optimizer on, the raw movement sequence is untouched
    /// (golden contract) and the optimized plan reaches the same state
    /// with no more bytes.
    #[test]
    fn simulate_with_optimizer_keeps_raw_trace() {
        let initial = cluster();

        let mut s_raw = initial.clone();
        let mut b_raw = Equilibrium::default();
        let raw = simulate(&mut b_raw, &mut s_raw, &SimOptions::default());
        assert!(raw.optimized.is_none());

        let mut s_opt = initial.clone();
        let mut b_opt = Equilibrium::default();
        let opts = SimOptions { plan: crate::plan::PlanConfig::optimized(), ..SimOptions::default() };
        let opt = simulate(&mut b_opt, &mut s_opt, &opts);

        assert_eq!(raw.movements.len(), opt.movements.len());
        for (a, b) in raw.movements.iter().zip(&opt.movements) {
            assert_eq!((a.pg, a.from, a.to, a.bytes), (b.pg, b.from, b.to, b.bytes));
        }
        let minimal = opt.optimized.expect("optimizer ran");
        assert!(minimal.len() <= opt.movements.len());
        assert!(opt.plan.bytes <= opt.plan.raw_bytes);
        // replaying the minimal plan lands on the same cluster
        let mut replay = initial;
        for m in &minimal {
            replay.apply_movement(m.pg, m.from, m.to).unwrap();
        }
        assert_eq!(replay.utilizations(), s_opt.utilizations());
        assert_eq!(replay.upmap_table(), s_opt.upmap_table());
    }

    #[test]
    fn compare_starts_from_identical_state() {
        let initial = cluster();
        let (mgr, eq) = compare(
            &initial,
            || Box::new(MgrBalancer::default()),
            || Box::new(Equilibrium::default()),
            &SimOptions::default(),
        );
        let v0_mgr = mgr.series.first().unwrap().variance;
        let v0_eq = eq.series.first().unwrap().variance;
        assert!((v0_mgr - v0_eq).abs() < 1e-15, "same initial state");
        // headline: equilibrium's final variance beats the baseline's
        let vf_mgr = mgr.series.last().unwrap().variance;
        let vf_eq = eq.series.last().unwrap().variance;
        assert!(vf_eq <= vf_mgr + 1e-12, "{vf_eq} vs {vf_mgr}");
    }

    #[test]
    fn sampling_stride_thins_series() {
        let mut state = cluster();
        let mut bal = Equilibrium::default();
        let res = simulate(&mut bal, &mut state, &SimOptions { max_moves: 10_000, sample_every: 5, ..SimOptions::default() });
        assert!(res.series.samples.len() <= res.movements.len() / 5 + 2);
        assert_eq!(res.series.last().unwrap().moves, res.movements.len());
    }

    /// `sample_every: 0` used to be a modulo-by-zero hazard; it now
    /// clamps to 1 (per-move sampling).
    #[test]
    fn sample_every_zero_is_clamped_to_one() {
        let initial = cluster();
        let mut s0 = initial.clone();
        let mut b0 = Equilibrium::default();
        let zero = simulate(&mut b0, &mut s0, &SimOptions { max_moves: 50, sample_every: 0, ..SimOptions::default() });
        let mut s1 = initial;
        let mut b1 = Equilibrium::default();
        let one = simulate(&mut b1, &mut s1, &SimOptions { max_moves: 50, sample_every: 1, ..SimOptions::default() });
        assert_eq!(zero.movements.len(), one.movements.len());
        assert_eq!(zero.series.samples.len(), one.series.samples.len());
        assert_eq!(zero.series.samples.len(), zero.movements.len() + 1);
    }

    /// The scenario adapter must emit the exact movement sequence of the
    /// historical select/apply loop (pure-balancing golden contract).
    #[test]
    fn simulate_matches_manual_next_move_loop() {
        let initial = cluster();

        let mut manual_state = initial.clone();
        let mut manual_bal = Equilibrium::default();
        let mut manual = Vec::new();
        while manual.len() < 10_000 {
            let Some(p) = manual_bal.next_move(&manual_state) else { break };
            manual.push(manual_state.apply_movement(p.pg, p.from, p.to).unwrap());
        }

        let mut state = initial;
        let mut bal = Equilibrium::default();
        let res = simulate(&mut bal, &mut state, &SimOptions::default());

        assert_eq!(res.movements.len(), manual.len());
        for (a, b) in res.movements.iter().zip(&manual) {
            assert_eq!((a.pg, a.from, a.to, a.bytes), (b.pg, b.from, b.to, b.bytes));
        }
        assert!(res.converged);
    }
}
