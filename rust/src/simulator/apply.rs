//! Drive a balancer against a simulated cluster and record the paper's
//! measurements (§3.2: "their effects were applied in a simulated Ceph
//! cluster in order to measure the movement amount, to predict the
//! resulting free space, and to track OSD utilizations and their
//! variance").

use std::time::Instant;

use crate::balancer::Balancer;
use crate::cluster::{ClusterState, Movement};

use super::timeseries::{Sample, TimeSeries};

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Hard movement cap (the paper's osdmaptool invocation used 10 000).
    pub max_moves: usize,
    /// Record a sample every `sample_every` moves (1 = every move, as the
    /// figures need; larger values keep huge runs cheap).
    pub sample_every: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { max_moves: 10_000, sample_every: 1 }
    }
}

/// Result of one balancer run.
#[derive(Debug)]
pub struct SimResult {
    /// Balancer name.
    pub balancer: String,
    /// Movements in plan order.
    pub movements: Vec<Movement>,
    /// Per-move series (first sample = initial state).
    pub series: TimeSeries,
    /// True if the balancer converged (returned None) rather than
    /// hitting the move cap.
    pub converged: bool,
    /// Total balancer compute time, seconds.
    pub total_calc_seconds: f64,
}

impl SimResult {
    pub fn total_moved_bytes(&self) -> u64 {
        self.movements.iter().map(|m| m.bytes).sum()
    }
}

/// Run `balancer` on `state` until convergence or the cap, timing each
/// movement computation (Figure 6's channel).
pub fn simulate(balancer: &mut dyn Balancer, state: &mut ClusterState, opts: &SimOptions) -> SimResult {
    let mut series = TimeSeries::default();
    series.samples.push(Sample::capture(state, 0, 0, 0.0));
    let mut movements = Vec::new();
    let mut moved_bytes = 0u64;
    let mut total_calc = 0.0;
    let mut converged = false;

    while movements.len() < opts.max_moves {
        let t0 = Instant::now();
        let proposal = balancer.next_move(state);
        let calc = t0.elapsed().as_secs_f64();
        total_calc += calc;
        let Some(p) = proposal else {
            converged = true;
            break;
        };
        let m = state
            .apply_movement(p.pg, p.from, p.to)
            .unwrap_or_else(|e| panic!("balancer '{}' proposed invalid move: {e}", balancer.name()));
        moved_bytes += m.bytes;
        movements.push(m);
        if movements.len() % opts.sample_every == 0 {
            series
                .samples
                .push(Sample::capture(state, movements.len(), moved_bytes, calc));
        }
    }
    // always capture the terminal state
    if series.last().map(|s| s.moves) != Some(movements.len()) {
        series
            .samples
            .push(Sample::capture(state, movements.len(), moved_bytes, 0.0));
    }

    SimResult {
        balancer: balancer.name().to_string(),
        movements,
        series,
        converged,
        total_calc_seconds: total_calc,
    }
}

/// Compare both balancers from the same initial state (the paper's
/// experimental protocol: "Both balancers start with the same cluster
/// state"). Returns (mgr result, equilibrium result).
pub fn compare<FA, FB>(
    initial: &ClusterState,
    mut make_baseline: FA,
    mut make_equilibrium: FB,
    opts: &SimOptions,
) -> (SimResult, SimResult)
where
    FA: FnMut() -> Box<dyn Balancer>,
    FB: FnMut() -> Box<dyn Balancer>,
{
    let mut state_a = initial.clone();
    let mut bal_a = make_baseline();
    let res_a = simulate(bal_a.as_mut(), &mut state_a, opts);

    let mut state_b = initial.clone();
    let mut bal_b = make_equilibrium();
    let res_b = simulate(bal_b.as_mut(), &mut state_b, opts);

    (res_a, res_b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::{Equilibrium, MgrBalancer};
    use crate::cluster::{ClusterState, Pool};
    use crate::crush::{CrushBuilder, DeviceClass, Level, Rule};
    use crate::util::units::{GIB, TIB};

    fn cluster() -> ClusterState {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..6 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            let size = if h < 2 { 8 * TIB } else { 4 * TIB };
            b.add_osd_bytes(host, size, DeviceClass::Hdd);
        }
        b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
        ClusterState::build(
            b.build().unwrap(),
            vec![Pool::replicated(1, "p", 3, 64, 0)],
            |_, i| (8 + (i % 9) as u64) * GIB,
        )
    }

    #[test]
    fn simulate_records_per_move_samples() {
        let mut state = cluster();
        let mut bal = Equilibrium::default();
        let res = simulate(&mut bal, &mut state, &SimOptions::default());
        assert!(res.converged);
        assert!(!res.movements.is_empty());
        // samples: initial + one per move
        assert_eq!(res.series.samples.len(), res.movements.len() + 1);
        // variance decreases monotonically for Equilibrium
        let vars: Vec<f64> = res.series.samples.iter().map(|s| s.variance).collect();
        for w in vars.windows(2) {
            assert!(w[1] <= w[0] + 1e-15, "variance must not increase: {w:?}");
        }
        assert_eq!(res.total_moved_bytes(), res.movements.iter().map(|m| m.bytes).sum());
    }

    #[test]
    fn move_cap_is_respected_and_flagged() {
        let mut state = cluster();
        let mut bal = Equilibrium::default();
        let res = simulate(&mut bal, &mut state, &SimOptions { max_moves: 2, sample_every: 1 });
        assert!(res.movements.len() <= 2);
        if res.movements.len() == 2 {
            assert!(!res.converged);
        }
    }

    #[test]
    fn compare_starts_from_identical_state() {
        let initial = cluster();
        let (mgr, eq) = compare(
            &initial,
            || Box::new(MgrBalancer::default()),
            || Box::new(Equilibrium::default()),
            &SimOptions::default(),
        );
        let v0_mgr = mgr.series.first().unwrap().variance;
        let v0_eq = eq.series.first().unwrap().variance;
        assert!((v0_mgr - v0_eq).abs() < 1e-15, "same initial state");
        // headline: equilibrium's final variance beats the baseline's
        let vf_mgr = mgr.series.last().unwrap().variance;
        let vf_eq = eq.series.last().unwrap().variance;
        assert!(vf_eq <= vf_mgr + 1e-12, "{vf_eq} vs {vf_mgr}");
    }

    #[test]
    fn sampling_stride_thins_series() {
        let mut state = cluster();
        let mut bal = Equilibrium::default();
        let res = simulate(&mut bal, &mut state, &SimOptions { max_moves: 10_000, sample_every: 5 });
        assert!(res.series.samples.len() <= res.movements.len() / 5 + 2);
        assert_eq!(res.series.last().unwrap().moves, res.movements.len());
    }
}
