//! Per-movement time series — the data behind the paper's figures.
//!
//! Figure 4/5 plot pool free space and OSD utilization variance against
//! the number of movements; Figure 6 plots the calculation time of each
//! movement. One [`Sample`] is recorded per movement (plus an initial
//! sample at move 0).

use std::collections::BTreeMap;

use crate::cluster::ClusterState;
use crate::crush::DeviceClass;

/// One row of the time series.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Number of movements applied so far.
    pub moves: usize,
    /// Cumulative bytes moved.
    pub moved_bytes: u64,
    /// Planning seconds attributed to this sample: one movement when
    /// sampling per move (`sample_every == 1`, the figures' setting),
    /// the whole chunk planned since the previous sample otherwise.
    /// 0 for the initial sample.
    pub calc_seconds: f64,
    /// Virtual cluster time at capture, seconds (0 unless the sample was
    /// taken by a timeline-driven run — the scenario engine stamps it).
    pub vtime: f64,
    /// Cluster-wide OSD utilization variance.
    pub variance: f64,
    /// Variance per device class present in the cluster.
    pub variance_by_class: BTreeMap<&'static str, f64>,
    /// Predicted free space (max_avail) per pool id, bytes.
    pub pool_avail: BTreeMap<u32, f64>,
}

impl Sample {
    /// Capture the current cluster state.
    pub fn capture(state: &ClusterState, moves: usize, moved_bytes: u64, calc_seconds: f64) -> Sample {
        let mut variance_by_class = BTreeMap::new();
        for class in DeviceClass::ALL {
            let present = (0..state.osd_count() as u32).any(|o| state.osd_class(o) == class);
            if present {
                variance_by_class
                    .insert(class.as_str(), state.utilization_variance_class(class));
            }
        }
        let pool_avail = state
            .pools
            .keys()
            .map(|&id| (id, state.pool_max_avail(id)))
            .collect();
        Sample {
            moves,
            moved_bytes,
            calc_seconds,
            vtime: 0.0,
            variance: state.utilization_variance(),
            variance_by_class,
            pool_avail,
        }
    }
}

/// The full series for one balancer run.
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pub samples: Vec<Sample>,
}

impl TimeSeries {
    pub fn first(&self) -> Option<&Sample> {
        self.samples.first()
    }

    pub fn last(&self) -> Option<&Sample> {
        self.samples.last()
    }

    /// Total space gained per pool (bytes): final − initial max_avail.
    pub fn gained_by_pool(&self) -> BTreeMap<u32, f64> {
        let (Some(first), Some(last)) = (self.first(), self.last()) else {
            return BTreeMap::new();
        };
        first
            .pool_avail
            .keys()
            .map(|&id| {
                let before = first.pool_avail.get(&id).copied().unwrap_or(0.0);
                let after = last.pool_avail.get(&id).copied().unwrap_or(0.0);
                (id, after - before)
            })
            .collect()
    }

    /// Sum of per-pool gains, optionally restricted to the given pools.
    pub fn total_gained(&self, pools: Option<&[u32]>) -> f64 {
        self.gained_by_pool()
            .iter()
            .filter(|(id, _)| pools.map(|ps| ps.contains(id)).unwrap_or(true))
            .map(|(_, g)| *g)
            .sum()
    }

    /// CSV rendering: one row per sample, one column per channel. Pool
    /// columns are `pool_<id>_avail`, classes `var_<class>`.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let Some(first) = self.first() else { return out };
        let classes: Vec<&str> = first.variance_by_class.keys().copied().collect();
        let pools: Vec<u32> = first.pool_avail.keys().copied().collect();
        out.push_str("moves,moved_bytes,calc_seconds,variance");
        for c in &classes {
            out.push_str(&format!(",var_{c}"));
        }
        for p in &pools {
            out.push_str(&format!(",pool_{p}_avail"));
        }
        out.push_str(",vtime");
        out.push('\n');
        for s in &self.samples {
            out.push_str(&format!(
                "{},{},{:.9},{:.12e}",
                s.moves, s.moved_bytes, s.calc_seconds, s.variance
            ));
            for c in &classes {
                out.push_str(&format!(
                    ",{:.12e}",
                    s.variance_by_class.get(c).copied().unwrap_or(f64::NAN)
                ));
            }
            for p in &pools {
                out.push_str(&format!(
                    ",{:.6e}",
                    s.pool_avail.get(p).copied().unwrap_or(f64::NAN)
                ));
            }
            out.push_str(&format!(",{:.3}", s.vtime));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Pool};
    use crate::crush::{CrushBuilder, Level, Rule};
    use crate::util::units::{GIB, TIB};

    fn state() -> ClusterState {
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for h in 0..4 {
            let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
            b.add_osd_bytes(host, 4 * TIB, DeviceClass::Hdd);
        }
        b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
        ClusterState::build(
            b.build().unwrap(),
            vec![Pool::replicated(1, "p", 3, 16, 0)],
            |_, _| GIB,
        )
    }

    #[test]
    fn capture_includes_present_classes_only() {
        let s = state();
        let sample = Sample::capture(&s, 0, 0, 0.0);
        assert!(sample.variance_by_class.contains_key("hdd"));
        assert!(!sample.variance_by_class.contains_key("ssd"));
        assert!(sample.pool_avail.contains_key(&1));
    }

    #[test]
    fn gained_by_pool_diffs_first_and_last() {
        let s = state();
        let mut ts = TimeSeries::default();
        ts.samples.push(Sample::capture(&s, 0, 0, 0.0));
        let mut second = Sample::capture(&s, 1, GIB, 0.001);
        *second.pool_avail.get_mut(&1).unwrap() += 100.0;
        ts.samples.push(second);
        let gained = ts.gained_by_pool();
        assert!((gained[&1] - 100.0).abs() < 1e-9);
        assert!((ts.total_gained(None) - 100.0).abs() < 1e-9);
        assert_eq!(ts.total_gained(Some(&[])), 0.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let s = state();
        let mut ts = TimeSeries::default();
        ts.samples.push(Sample::capture(&s, 0, 0, 0.0));
        ts.samples.push(Sample::capture(&s, 1, 42, 0.002));
        let csv = ts.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("moves,moved_bytes,calc_seconds,variance"));
        assert!(lines[0].contains("var_hdd"));
        assert!(lines[0].contains("pool_1_avail"));
        assert!(lines[0].ends_with(",vtime"));
        assert!(lines[2].starts_with("1,42,"));
    }
}
