//! Fleet runner suite (RFC 0004): thread-count determinism of the
//! aggregate output, baseline JSON round trips, the statistical gate's
//! pass/fail behavior — including the committed-baseline perturbation
//! failure the CI contract requires — and custom-spec sweeps through
//! the seed-override hook.

use equilibrium::fleet::{
    gate, parse_baseline, run_library, sweep_case, sweep_spec, Distribution, FleetConfig,
    FleetError, GateConfig, METRICS,
};
use equilibrium::generator::clusters;
use equilibrium::plan::PlanConfig;
use equilibrium::scenario::ScenarioSpec;
use equilibrium::simulator::WorkloadModel;
use equilibrium::util::parallel::with_threads;
use equilibrium::util::units::GIB;

fn small_cfg() -> FleetConfig {
    FleetConfig { seeds: 3, reduced: true, ..FleetConfig::default() }
}

/// The headline determinism pin: the serialized sweep aggregate is
/// byte-identical at 1, 2, and 4 worker threads.
#[test]
fn sweep_aggregates_are_byte_identical_across_thread_counts() {
    let names = ["pool-growth", "device-failure"];
    let cfg = small_cfg();
    let t1 = with_threads(1, || run_library(&names, &cfg)).unwrap().to_baseline().render();
    for threads in [2, 4] {
        let tn = with_threads(threads, || run_library(&names, &cfg))
            .unwrap()
            .to_baseline()
            .render();
        assert_eq!(t1, tn, "fleet aggregate diverged at {threads} threads");
    }
}

#[test]
fn baseline_round_trips_through_json() {
    let b = run_library(&["pool-decommission"], &small_cfg()).unwrap().to_baseline();
    let parsed = parse_baseline(&b.render()).unwrap();
    assert_eq!(parsed, b);
    assert_eq!(parsed.meta.seeds, 3);
    assert_eq!(parsed.scenarios.len(), 1);
    for s in &parsed.scenarios {
        for m in METRICS {
            let d = s.metrics.get(m).unwrap_or_else(|| panic!("metric '{m}' missing"));
            assert!(d.mean.is_finite(), "{m}: non-finite mean");
            assert!(d.min <= d.p50 && d.p50 <= d.p90 && d.p90 <= d.p99 && d.p99 <= d.max);
        }
    }
    // wall-clock channels must never be committed
    assert!(!b.render().contains("calc"), "baselines must exclude wall-clock metrics");
}

/// The acceptance-criterion demonstration: a deterministic replay
/// passes the gate against its own baseline, and a perturbed baseline
/// fails it.
#[test]
fn gate_passes_on_identical_sweep_and_fails_on_perturbation() {
    let base = run_library(&["device-failure"], &small_cfg()).unwrap().to_baseline();
    let report = gate(&base, &base, &GateConfig::default());
    assert!(report.passed(), "self-gate must pass: {:?}", report.violations);
    assert!(report.checked >= METRICS.len() * 7, "every field of every metric is checked");

    // drift inside the tolerance band passes
    let mut near = base.clone();
    near.scenarios[0].metrics.get_mut("raw_bytes").unwrap().mean *= 1.001;
    assert!(gate(&near, &base, &GateConfig::default()).passed());

    // a 10% drift at p90 (the optimizer suddenly moving more bytes) fails
    let mut bad = base.clone();
    bad.scenarios[0].metrics.get_mut("raw_bytes").unwrap().p90 *= 1.10;
    let report = gate(&bad, &base, &GateConfig::default());
    assert!(!report.passed(), "perturbed baseline must fail the gate");
    assert!(
        report
            .violations
            .iter()
            .any(|v| v.metric == "raw_bytes" && v.field == "p90"),
        "the perturbed field must be named: {:?}",
        report.violations
    );

    // structural drift is a mismatch, not a silent pass
    let mut other = base.clone();
    other.meta.seeds += 1;
    assert!(!gate(&other, &base, &GateConfig::default()).passed());
}

#[test]
fn custom_spec_sweeps_with_seed_override() {
    let spec = ScenarioSpec::new("custom", 0)
        .workload(WorkloadModel::ZipfPools { exponent: 1.1 }, 8 * GIB, 60.0)
        .balance(50);
    let cfg = FleetConfig { seeds: 2, seed_base: 7, reduced: true, ..FleetConfig::default() };
    let sweep = sweep_spec(&spec, &cfg, clusters::demo).unwrap();
    assert_eq!(sweep.runs.len(), 2);
    assert_eq!(sweep.runs[0].seed, 7);
    assert_eq!(sweep.runs[1].seed, 8);
    // different seeds rebuild the cluster AND reseed the workload, so
    // the trajectories must differ
    assert_ne!(
        (sweep.runs[0].raw_bytes, sweep.runs[0].variance.to_bits()),
        (sweep.runs[1].raw_bytes, sweep.runs[1].variance.to_bits()),
    );
    let dist = sweep.summarize();
    assert_eq!(dist.name, "custom");
    let moves = &dist.metrics["planned_moves"];
    assert!(moves.max >= moves.min);
}

/// Raw vs phased sweeps share the planning stream; the pipeline may
/// only shrink what is physically executed.
#[test]
fn pipeline_sweep_never_executes_more_than_planned() {
    let cfg = FleetConfig {
        seeds: 2,
        reduced: true,
        plan: PlanConfig::phased(),
        ..FleetConfig::default()
    };
    let sweep = sweep_case("pool-decommission", &cfg).unwrap();
    for r in &sweep.runs {
        assert!(
            r.executed_bytes <= r.raw_bytes,
            "seed {}: executed {} > planned {}",
            r.seed,
            r.executed_bytes,
            r.raw_bytes
        );
        assert!(r.executed_moves <= r.planned_moves);
        assert!(r.phases >= 1, "seed {}: a moving round must execute phases", r.seed);
    }
}

#[test]
fn unknown_scenarios_are_typed_errors() {
    let cfg = small_cfg();
    assert!(matches!(sweep_case("nope", &cfg), Err(FleetError::UnknownScenario(_))));
    assert!(matches!(
        run_library(&["pool-growth", "nope"], &cfg),
        Err(FleetError::UnknownScenario(_))
    ));
}

#[test]
fn stats_kernel_is_exact() {
    let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
    let d = Distribution::from_values(&xs);
    assert_eq!(d.p50, 50.0);
    assert_eq!(d.p90, 90.0);
    assert_eq!(d.p99, 99.0);
    assert_eq!(d.min, 1.0);
    assert_eq!(d.max, 100.0);
    assert!((d.mean - 50.5).abs() < 1e-12);
    // population stddev of 1..N is sqrt((N² − 1) / 12)
    let expect = ((100.0f64 * 100.0 - 1.0) / 12.0).sqrt();
    assert!((d.stddev - expect).abs() < 1e-9);

    let one = Distribution::from_values(&[3.5]);
    assert_eq!(
        (one.mean, one.stddev, one.p50, one.p99, one.min, one.max),
        (3.5, 0.0, 3.5, 3.5, 3.5, 3.5)
    );
    assert_eq!(Distribution::from_values(&[]), Distribution::default());

    // unsorted input is sorted internally
    let d2 = Distribution::from_values(&[9.0, 1.0, 5.0]);
    assert_eq!(d2.p50, 5.0);
    assert_eq!(d2.min, 1.0);
    assert_eq!(d2.max, 9.0);
}
