//! CLI integration tests: spawn the `equilibrium` binary (built by
//! cargo for this profile) and assert exit codes plus the stable
//! first-line output of the listing / fleet / report surfaces that the
//! CI jobs and operator scripts key on.

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_equilibrium")
}

#[test]
fn scenario_list_has_stable_first_line() {
    let out = Command::new(bin()).args(["scenario", "list"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert_eq!(
        stdout.lines().next().unwrap(),
        "library scenarios (seeded, deterministic):"
    );
    for name in equilibrium::scenario::ALL {
        assert!(stdout.contains(name), "scenario '{name}' missing from the listing");
    }
}

#[test]
fn fleet_run_smoke_report_and_gate() {
    let dir = std::env::temp_dir().join(format!("eq_fleet_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let baseline_path = dir.join("FLEET_baseline.json");

    // ---- fleet run --smoke: stable first line, baseline emitted ---------
    let out = Command::new(bin())
        .args(["fleet", "run", "--smoke", "--seeds", "2", "--quiet"])
        .args(["--out", baseline_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "fleet run failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert_eq!(
        stdout.lines().next().unwrap(),
        "fleet: sweeping 7 scenario(s) × 2 seeds (reduced, raw pipeline)"
    );
    let text = std::fs::read_to_string(&baseline_path).unwrap();
    let parsed = equilibrium::fleet::parse_baseline(&text).unwrap();
    assert_eq!(parsed.scenarios.len(), 7);
    assert_eq!(parsed.meta.seeds, 2);

    // ---- report fleet: table + CSV --------------------------------------
    let out = Command::new(bin())
        .args(["report", "fleet", "--baseline", baseline_path.to_str().unwrap()])
        .args(["--out-dir", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "report fleet failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(
        stdout
            .lines()
            .next()
            .unwrap()
            .starts_with("Fleet summary — 7 scenarios × 2 seeds"),
        "unexpected first line: {stdout}"
    );
    assert!(stdout.contains("pool-growth"));
    let csv = std::fs::read_to_string(dir.join("fleet_summary.csv")).unwrap();
    assert!(csv.lines().next().unwrap().starts_with("scenario,metric,mean"));

    // ---- fleet gate: a deterministic replay passes ----------------------
    let out = Command::new(bin())
        .args(["fleet", "gate", "--baseline", baseline_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "self-gate failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(stdout.contains("gate OK"), "{stdout}");

    // ---- and a perturbed baseline fails with a non-zero exit ------------
    let mut bad = parsed.clone();
    let d = bad.scenarios[0].metrics.get_mut("raw_bytes").unwrap();
    d.mean *= 1.5;
    d.p90 *= 1.5;
    let bad_path = dir.join("FLEET_bad.json");
    std::fs::write(&bad_path, bad.render()).unwrap();
    let out = Command::new(bin())
        .args(["fleet", "gate", "--baseline", bad_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success(), "perturbed baseline must fail the gate");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("violation"), "violations must be reported: {stderr}");
    assert!(stderr.contains("raw_bytes"), "the drifted metric must be named: {stderr}");

    // ---- malformed baseline: clean error, no panic ----------------------
    let junk_path = dir.join("junk.json");
    std::fs::write(&junk_path, "{not json").unwrap();
    let out = Command::new(bin())
        .args(["fleet", "gate", "--baseline", junk_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error:"));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fuzz_gen_and_spec_replay_round_trip() {
    let dir = std::env::temp_dir().join(format!("eq_fuzz_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec_path = dir.join("spec.json");

    // ---- fuzz gen emits a loadable spec ---------------------------------
    let out = Command::new(bin())
        .args(["fuzz", "gen", "--seed", "7", "--reduced"])
        .args(["--out", spec_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "fuzz gen failed: {}", String::from_utf8_lossy(&out.stderr));
    let spec = equilibrium::scenario::serde::load_file(&spec_path).unwrap();
    assert_eq!(spec.name, "fuzz-kitchen-sink-00000007");
    assert_eq!(spec.seed, 7);

    // ---- scenario run --spec replays it clean ---------------------------
    let out = Command::new(bin())
        .args(["scenario", "run", "--spec", spec_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "replay failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert_eq!(
        stdout.lines().next().unwrap(),
        format!(
            "scenario: replaying spec 'fuzz-kitchen-sink-00000007' ({} events, seed 7)",
            spec.events.len()
        )
    );
    assert!(stdout.contains("clean: all invariants held"), "{stdout}");

    // ---- malformed spec: clean error, non-zero exit ---------------------
    let junk_path = dir.join("junk.json");
    std::fs::write(&junk_path, "{not json").unwrap();
    let out = Command::new(bin())
        .args(["scenario", "run", "--spec", junk_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success(), "malformed spec must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error:"), "{stderr}");
    assert!(stderr.contains("invalid JSON"), "the parse failure must be explained: {stderr}");

    // a structurally-valid JSON document that is not a spec also fails
    let foreign_path = dir.join("foreign.json");
    std::fs::write(&foreign_path, "{\"format\": \"something-else\"}\n").unwrap();
    let out = Command::new(bin())
        .args(["scenario", "run", "--spec", foreign_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error:"));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fuzz_run_smoke_is_clean_and_reports() {
    let dir = std::env::temp_dir().join(format!("eq_fuzz_run_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let report_path = dir.join("fuzz.json");

    let out = Command::new(bin())
        .args(["fuzz", "run", "--cases", "4", "--reduced", "--quiet"])
        .args(["--out", report_path.to_str().unwrap()])
        .args(["--promote-dir", dir.join("promoted").to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "fuzz run failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert_eq!(
        stdout.lines().next().unwrap(),
        "fuzz: sweeping 4 case(s) across 4 profile(s) (reduced)"
    );
    let report = std::fs::read_to_string(&report_path).unwrap();
    let json = equilibrium::util::json::Json::parse(&report).unwrap();
    assert_eq!(json.get("cases").and_then(|j| j.as_u64()), Some(4));
    assert_eq!(json.get("violations").and_then(|j| j.as_u64()), Some(0));
    // a clean sweep must not create the promotion directory
    assert!(!dir.join("promoted").exists(), "clean sweeps promote nothing");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_rejects_bad_arguments() {
    // unknown action
    let out = Command::new(bin()).args(["fleet", "nope"]).output().unwrap();
    assert!(!out.status.success());
    // unknown scenario name
    let out = Command::new(bin())
        .args(["fleet", "run", "--smoke", "--seeds", "1", "--name", "nope"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown library scenario"));
    // gate without a baseline
    let out = Command::new(bin()).args(["fleet", "gate"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--baseline is required"));
}
