//! Columnar-core equivalence (RFC 0002): the arena-backed
//! `ClusterState` must be indistinguishable from its serialized self,
//! and parallel construction must equal serial construction **exactly**.
//!
//! * For seeded random clusters (with real upmap entries planted by the
//!   balancer), a `dump.rs` round trip reproduces identical
//!   utilizations, upmap tables, per-PG columns and `verify()` results.
//! * Building the same cluster under `threads=4` and `threads=1` yields
//!   byte-identical dumps — the fixed-chunk / ordered-reduction
//!   contract of `util::parallel`.

use equilibrium::balancer::{Balancer, Equilibrium};
use equilibrium::cluster::dump;
use equilibrium::cluster::{add_hosts, ClusterState, HostSpec, Pool};
use equilibrium::generator::clusters;
use equilibrium::generator::synth::random_cluster;
use equilibrium::util::parallel;
use equilibrium::util::prop::check_seeded;
use equilibrium::util::rng::Rng;
use equilibrium::util::units::{GIB, TIB};

/// Plant some upmap entries so the exception table is non-trivial.
fn balanced(mut state: ClusterState) -> ClusterState {
    let mut bal = Equilibrium::default();
    let _ = bal.propose_batch(&mut state, 40);
    state
}

fn assert_states_equal(a: &ClusterState, b: &ClusterState) -> Result<(), String> {
    if a.utilizations() != b.utilizations() {
        return Err("utilizations differ".into());
    }
    if a.upmap_table() != b.upmap_table() {
        return Err("upmap tables differ".into());
    }
    if a.upmap_entry_count() != b.upmap_entry_count() {
        return Err("upmap entry counts differ".into());
    }
    if a.pg_count() != b.pg_count() {
        return Err("pg counts differ".into());
    }
    for (x, y) in a.pgs().zip(b.pgs()) {
        if x.id() != y.id() || x.shard_bytes() != y.shard_bytes() || x.acting() != y.acting() {
            return Err(format!("pg {} columns differ", x.id()));
        }
    }
    let (va, vb) = (a.verify(), b.verify());
    if va != vb {
        return Err(format!("verify() results differ: {va:?} vs {vb:?}"));
    }
    if !va.is_empty() {
        return Err(format!("invariants violated: {va:?}"));
    }
    Ok(())
}

/// Arena-backed state ↔ dump round trip: identical utilizations, upmap
/// tables and verify() results.
#[test]
fn arena_state_matches_dump_roundtrip() {
    check_seeded("arena-roundtrip", 0xA2E4A, 10, |rng| {
        let state = balanced(random_cluster(rng));
        let loaded = dump::load(&dump::dump(&state)).map_err(|e| e.to_string())?;
        assert_states_equal(&state, &loaded)?;
        // and the round trip is byte-stable
        if dump::dump(&loaded) != dump::dump(&state) {
            return Err("second dump differs from first".into());
        }
        Ok(())
    });
}

/// Parallel build (threads=4) equals serial build (threads=1) exactly —
/// bit-identical dumps, not just statistically similar clusters.
#[test]
fn parallel_build_equals_serial_build() {
    check_seeded("parallel-build", 0x9A11E1, 8, |rng| {
        let seed = rng.next_u64();
        let serial = parallel::with_threads(1, || random_cluster(&mut Rng::new(seed)));
        let par = parallel::with_threads(4, || random_cluster(&mut Rng::new(seed)));
        assert_states_equal(&serial, &par)?;
        if dump::dump(&serial) != dump::dump(&par) {
            return Err("parallel dump differs from serial dump".into());
        }
        Ok(())
    });
}

/// The same holds on a Table-1 cluster, and the balancer's decisions on
/// the two builds are move-for-move identical.
#[test]
fn parallel_build_of_paper_cluster_balances_identically() {
    let serial = parallel::with_threads(1, || clusters::by_name("a", 0).unwrap().state);
    let par = parallel::with_threads(4, || clusters::by_name("a", 0).unwrap().state);
    assert_states_equal(&serial, &par).unwrap();

    let run = |initial: &ClusterState| {
        let mut s = initial.clone();
        let mut bal = Equilibrium::default();
        let mut out = Vec::new();
        while out.len() < 2_000 {
            let Some(p) = bal.next_move(&s) else { break };
            s.apply_movement(p.pg, p.from, p.to).unwrap();
            out.push((p.pg, p.from, p.to, p.bytes));
        }
        out
    };
    // plan on the serial build at 1 thread, on the parallel build at 4:
    // scoring fan-out must not change a single decision
    let a = parallel::with_threads(1, || run(&serial));
    let b = parallel::with_threads(4, || run(&par));
    assert_eq!(a, b, "thread count changed the move sequence");
}

/// The flattened (offset-table) upmap encoding of RFC 0006 must survive
/// arena restriding: host expansion appends device ids, pool creation
/// appends a stripe and re-derives every dense index. Existing upmap
/// entries may not shift, and the dump/load round trip must stay
/// byte-identical through both events.
#[test]
fn upmap_offset_table_survives_restriding() {
    check_seeded("upmap-restride", 0x0FF5E7, 6, |rng| {
        let mut state = balanced(random_cluster(rng));
        if state.upmap_entry_count() == 0 {
            // nothing to pin on this instance; the seeded sweep covers
            // plenty of clusters where the balancer planted exceptions
            return Ok(());
        }
        let before = state.upmap_table();

        // expansion: new hosts and devices append to the id space
        add_hosts(&mut state, &HostSpec::hdd(2, 3, 4 * TIB)).map_err(|e| e.to_string())?;
        if state.upmap_table() != before {
            return Err("host expansion shifted upmap entries".into());
        }
        let loaded = dump::load(&dump::dump(&state)).map_err(|e| e.to_string())?;
        assert_states_equal(&state, &loaded)?;
        if dump::dump(&loaded) != dump::dump(&state) {
            return Err("post-expansion round trip is not byte-stable".into());
        }

        // pool creation: a new stripe restrides the arena columns
        let next_id = state.pools.keys().max().copied().unwrap_or(0) + 1;
        let rule_id = state.pools.values().next().expect("pools exist").rule_id;
        state
            .add_pool(Pool::replicated(next_id, "restride_probe", 3, 16, rule_id), |i| {
                (1 + i as u64) * GIB
            })
            .map_err(|e| e.to_string())?;
        if state.upmap_table() != before {
            return Err("pool creation disturbed existing upmap entries".into());
        }
        let loaded = dump::load(&dump::dump(&state)).map_err(|e| e.to_string())?;
        assert_states_equal(&state, &loaded)?;
        if dump::dump(&loaded) != dump::dump(&state) {
            return Err("post-add_pool round trip is not byte-stable".into());
        }
        Ok(())
    });
}
