//! Plan-invariant property suite (RFC 0003): the optimizer and the
//! phased scheduler pinned against random clusters and random plans.
//!
//! The contract, for any cluster and any valid plan:
//! (a) the optimized plan reaches a final `ClusterState` identical to
//!     the raw plan's — acting slots, upmap table, per-OSD accounting;
//! (b) every optimized move satisfies the pool's CRUSH slot
//!     constraints at its position in the sequence;
//! (c) the optimized plan never moves more bytes (or moves) than raw;
//! (d) the whole pipeline is byte-identical across thread counts
//!     (`EQUILIBRIUM_THREADS=1/4` — the RFC 0002 determinism contract
//!     extends to the pipeline).
//!
//! Plus the scheduler's structural invariants (permutation, per-OSD and
//! per-domain caps, same-PG phase exclusion, sequential applicability)
//! and the measurable-savings acceptance scenarios: churn plans whose
//! later rounds revert earlier placements must execute strictly fewer
//! bytes in strictly less virtual time, landing on the same balance.

use equilibrium::balancer::constraints::{check_move, legal_destinations};
use equilibrium::balancer::upmap_script::{diff_plan, parse_script, render_plan};
use equilibrium::balancer::{Balancer, Equilibrium};
use equilibrium::cluster::{ClusterState, Movement, PgId};
use equilibrium::coordinator::execute_plan;
use equilibrium::crush::{NodeId, OsdId};
use equilibrium::generator::clusters;
use equilibrium::generator::synth::random_cluster;
use equilibrium::plan::{net_relocations, optimize_plan, schedule_plan, PlanConfig, ScheduleConfig};
use equilibrium::util::parallel;
use equilibrium::util::prop::{check_seeded, check_shrinking};
use equilibrium::util::rng::Rng;

/// Random valid plan: legal moves on a scratch state, with a bias
/// toward reverting earlier moves so chains and round trips occur.
fn random_plan(state: &mut ClusterState, rng: &mut Rng, target: usize) -> Vec<Movement> {
    let pgs: Vec<PgId> = state.pgs().map(|p| p.id()).collect();
    let mut plan: Vec<Movement> = Vec::new();
    let mut attempts = 0;
    while plan.len() < target && attempts < target * 20 {
        attempts += 1;
        if !plan.is_empty() && rng.chance(0.3) {
            // revert a random earlier move if still legal
            let m = *rng.choose(&plan).unwrap();
            if check_move(state, m.pg, m.to, m.from).is_ok() {
                plan.push(state.apply_movement(m.pg, m.to, m.from).unwrap());
            }
            continue;
        }
        let pg = *rng.choose(&pgs).unwrap();
        let devices: Vec<OsdId> = state.pg(pg).unwrap().devices().collect();
        let Some(&from) = rng.choose(&devices) else { continue };
        let dests = legal_destinations(state, pg, from);
        let Some(&to) = rng.choose(&dests) else { continue };
        plan.push(state.apply_movement(pg, from, to).unwrap());
    }
    plan
}

fn apply_all(initial: &ClusterState, plan: &[Movement]) -> ClusterState {
    let mut s = initial.clone();
    for m in plan {
        s.apply_movement(m.pg, m.from, m.to)
            .unwrap_or_else(|e| panic!("plan not applicable: {e}"));
    }
    s
}

/// Byte-level state equivalence: acting slots, upmap table, accounting.
fn assert_states_equal(a: &ClusterState, b: &ClusterState, label: &str) -> Result<(), String> {
    if a.upmap_table() != b.upmap_table() {
        return Err(format!("{label}: upmap tables differ"));
    }
    for (pa, pb) in a.pgs().zip(b.pgs()) {
        if pa.id() != pb.id() || pa.acting() != pb.acting() {
            return Err(format!("{label}: pg {} acting differs", pa.id()));
        }
    }
    for o in 0..a.osd_count() as OsdId {
        if a.osd_used(o) != b.osd_used(o) {
            return Err(format!("{label}: osd.{o} usage differs"));
        }
    }
    Ok(())
}

/// Properties (a), (b), (c) on random clusters and random plans.
#[test]
fn optimizer_reaches_identical_state_within_raw_budget() {
    check_seeded("plan-opt-equivalence", 0x9A_0001, 24, |rng| {
        let initial = random_cluster(rng);
        let mut raw_state = initial.clone();
        let raw = random_plan(&mut raw_state, rng, 50);

        let opt = optimize_plan(&initial, &raw);
        // (c) never more work than the raw plan
        if opt.movements.len() > raw.len() {
            return Err(format!("{} opt moves > {} raw", opt.movements.len(), raw.len()));
        }
        let raw_bytes: u64 = raw.iter().map(|m| m.bytes).sum();
        if opt.stats.bytes > raw_bytes {
            return Err(format!("{} opt bytes > {} raw", opt.stats.bytes, raw_bytes));
        }
        if opt.stats.fell_back {
            return Err("optimizer fell back on a valid random plan".into());
        }
        // (b) CRUSH slot constraints hold at every step of the sequence
        let mut opt_state = initial.clone();
        for m in &opt.movements {
            if let Err(v) = check_move(&opt_state, m.pg, m.from, m.to) {
                return Err(format!("optimized move {m} violates constraints: {v:?}"));
            }
            opt_state
                .apply_movement(m.pg, m.from, m.to)
                .map_err(|e| format!("optimized move {m} not applicable: {e}"))?;
        }
        // (a) identical final state
        assert_states_equal(&opt_state, &raw_state, "optimized vs raw")?;
        let problems = opt_state.verify();
        if !problems.is_empty() {
            return Err(format!("invariants violated: {problems:?}"));
        }
        Ok(())
    });
}

/// Property (d): the full pipeline is bit-identical at 1 and 4 threads
/// — cluster build, planning, optimization, and scheduling.
#[test]
fn pipeline_is_deterministic_across_thread_counts() {
    type Trace = (Vec<(PgId, OsdId, OsdId, u64)>, Vec<usize>);
    let run = |threads: usize| -> Trace {
        parallel::with_threads(threads, || {
            let mut rng = Rng::new(0xD17E_0003);
            let initial = random_cluster(&mut rng);
            let mut state = initial.clone();
            let mut bal = Equilibrium::default();
            let raw = bal.propose_batch(&mut state, 400);
            let opt = optimize_plan(&initial, &raw);
            let phased = schedule_plan(&initial, &opt.movements, &ScheduleConfig::default());
            (
                phased
                    .movements()
                    .map(|m| (m.pg, m.from, m.to, m.bytes))
                    .collect(),
                phased.phases.iter().map(|p| p.len()).collect(),
            )
        })
    };
    let t1 = run(1);
    let t4 = run(4);
    assert_eq!(t1.0, t4.0, "move sequences diverged across thread counts");
    assert_eq!(t1.1, t4.1, "phase assignments diverged across thread counts");
}

/// Scheduler invariants on random clusters/plans under varied caps —
/// ported to `check_shrinking`: the generated sequence is the optimized
/// movement plan, and because prefixes of a sequentially-valid plan are
/// themselves valid plans, a failure bisects down to the few moves that
/// actually break the scheduler instead of the full 40-move plan.
#[test]
fn scheduler_invariants_hold_for_random_plans() {
    // gen and prop are separate closures: the cluster and caps the plan
    // was generated against travel through this cell
    let ctx: std::cell::RefCell<Option<(ClusterState, ScheduleConfig)>> =
        std::cell::RefCell::new(None);
    check_shrinking(
        "plan-sched-invariants",
        0x5C_4ED0,
        16,
        |rng| {
            let initial = random_cluster(rng);
            let mut raw_state = initial.clone();
            let raw = random_plan(&mut raw_state, rng, 40);
            let opt = optimize_plan(&initial, &raw);
            let cfg = ScheduleConfig {
                max_backfills_per_osd: 1 + rng.index(2),
                max_backfills_per_domain: 1 + rng.index(3),
                ..ScheduleConfig::default()
            };
            *ctx.borrow_mut() = Some((initial, cfg));
            opt.movements
        },
        |plan| {
            let guard = ctx.borrow();
            let (initial, cfg) = guard.as_ref().expect("gen runs before prop");
            let phased = schedule_plan(initial, plan, cfg);

            // permutation of the input
            let key = |m: &Movement| (m.pg, m.from, m.to, m.bytes);
            let mut want: Vec<_> = plan.iter().map(key).collect();
            let mut got: Vec<_> = phased.movements().map(key).collect();
            want.sort();
            got.sort();
            if want != got {
                return Err("schedule is not a permutation of the plan".into());
            }

            for (i, phase) in phased.phases.iter().enumerate() {
                if phase.is_empty() {
                    return Err(format!("phase {i} is empty"));
                }
                let mut osd_load = std::collections::BTreeMap::<OsdId, usize>::new();
                let mut dom_load = std::collections::BTreeMap::<NodeId, usize>::new();
                let mut pgs = Vec::new();
                for m in phase {
                    if pgs.contains(&m.pg) {
                        return Err(format!("phase {i}: pg {} scheduled twice", m.pg));
                    }
                    pgs.push(m.pg);
                    for o in [m.from, m.to] {
                        *osd_load.entry(o).or_insert(0) += 1;
                    }
                    let df = initial.crush.ancestor_at(m.from as NodeId, cfg.domain_level);
                    let dt = initial.crush.ancestor_at(m.to as NodeId, cfg.domain_level);
                    let mut doms: Vec<NodeId> = df.into_iter().chain(dt).collect();
                    doms.dedup();
                    for d in doms {
                        *dom_load.entry(d).or_insert(0) += 1;
                    }
                }
                if osd_load.values().any(|&l| l > cfg.max_backfills_per_osd) {
                    return Err(format!("phase {i}: per-OSD cap violated"));
                }
                if dom_load.values().any(|&l| l > cfg.max_backfills_per_domain) {
                    return Err(format!("phase {i}: per-domain cap violated"));
                }
            }

            // phases apply in order and land on the plan's state
            let mut s = initial.clone();
            for m in phased.movements() {
                s.apply_movement(m.pg, m.from, m.to)
                    .map_err(|e| format!("scheduled order not applicable: {e}"))?;
            }
            assert_states_equal(&s, &apply_all(initial, plan), "scheduled vs plan")?;
            Ok(())
        },
    );
}

/// Upmap-script round trip over the pipeline: render the optimized
/// plan, parse it back, and the table diff reproduces the plan.
#[test]
fn upmap_script_round_trips_optimized_plans() {
    check_seeded("plan-upmap-roundtrip", 0x0F_F00D, 16, |rng| {
        let initial = random_cluster(rng);
        let mut raw_state = initial.clone();
        let raw = random_plan(&mut raw_state, rng, 40);
        let opt = optimize_plan(&initial, &raw);

        let script = render_plan(&initial, &opt.movements)
            .map_err(|e| format!("render failed: {e}"))?
            .join("\n");
        let table = parse_script(&script).map_err(|e| format!("parse failed: {e}"))?;
        // the parsed table is exactly the final state's exception table
        let done = apply_all(&initial, &opt.movements);
        if table != done.upmap_table() {
            return Err("parsed table differs from the final upmap table".into());
        }
        // ... and diffing it against the initial state reproduces the
        // optimized plan's net relocations (fold to nets: the optimizer
        // may realize a slot-swap cycle via an intermediate hop, and
        // diff order is canonical, not execution order)
        let key = |m: &Movement| (m.pg, m.from, m.to, m.bytes);
        let net = diff_plan(&initial, &table).map_err(|e| format!("diff failed: {e}"))?;
        let mut want: Vec<_> = net.iter().map(key).collect();
        want.sort(); // diff is already one net move per slot — no folding
        let mut got: Vec<_> = net_relocations(&opt.movements).iter().map(key).collect();
        got.sort();
        if want != got {
            return Err(format!("diff nets {} moves, optimizer nets {}", want.len(), got.len()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Measurable-savings acceptance: churn timelines where later rounds
// revert earlier placements. The pipeline must land on the same balance
// with strictly fewer bytes moved and a strictly lower virtual-time
// makespan than executing the raw plan.
// ---------------------------------------------------------------------

/// Balance to convergence, then revert every move after `keep` in
/// reverse order — the shape a later scenario round produces when it
/// undoes earlier placements (pool decommission, post-failure
/// re-leveling). Returns (initial, raw plan, final state).
fn churn_plan(seed: u64, keep: impl Fn(usize) -> usize) -> (ClusterState, Vec<Movement>, ClusterState) {
    let initial = clusters::demo(seed);
    let mut state = initial.clone();
    let mut bal = Equilibrium::default();
    let forward = bal.propose_batch(&mut state, 10_000);
    assert!(forward.len() >= 4, "demo cluster must need balancing");
    let k = keep(forward.len());
    let mut raw = forward.clone();
    for m in forward[k..].iter().rev() {
        raw.push(state.apply_movement(m.pg, m.to, m.from).unwrap());
    }
    (initial, raw, state)
}

fn assert_churn_savings(name: &str, seed: u64, keep: impl Fn(usize) -> usize) {
    let (initial, raw, final_state) = churn_plan(seed, keep);
    let n = initial.osd_count();
    let sched = ScheduleConfig {
        // generous domain headroom: the comparison isolates coalescing
        max_backfills_per_domain: 8,
        ..ScheduleConfig::default()
    };

    let opt = optimize_plan(&initial, &raw);
    let phased = schedule_plan(&initial, &opt.movements, &sched);

    let raw_bytes: u64 = raw.iter().map(|m| m.bytes).sum();
    assert!(
        opt.stats.bytes < raw_bytes,
        "{name}: optimized bytes {} must be strictly below raw {}",
        opt.stats.bytes,
        raw_bytes
    );
    let raw_makespan = execute_plan(&raw, &sched.executor, n).unwrap().makespan;
    let phased_makespan = phased.makespan(&sched.executor, n);
    assert!(
        phased_makespan < raw_makespan,
        "{name}: phased makespan {phased_makespan} must beat raw {raw_makespan}"
    );

    // same final balance, bit for bit
    let opt_state = apply_all(&initial, &opt.movements);
    assert_eq!(
        opt_state.utilization_variance(),
        final_state.utilization_variance(),
        "{name}: optimized plan must reach the raw plan's variance"
    );
    assert_states_equal(&opt_state, &final_state, name).unwrap();
}

/// Full reversal: the whole balance is undone by later churn — the
/// optimized plan is empty and executes in zero time.
#[test]
fn full_reversal_churn_cancels_to_nothing() {
    let (initial, raw, final_state) = churn_plan(3, |_| 0);
    let opt = optimize_plan(&initial, &raw);
    assert!(opt.movements.is_empty(), "full round trip must cancel entirely");
    assert_eq!(opt.stats.bytes, 0);
    assert!(opt.stats.raw_bytes > 0);
    assert_states_equal(&initial, &final_state, "full reversal").unwrap();
    let phased = schedule_plan(&initial, &opt.movements, &ScheduleConfig::default());
    assert_eq!(phased.move_count(), 0);
    assert_churn_savings("full-reversal", 3, |_| 0);
}

/// Partial reversal: three quarters of the balance is later undone —
/// the pipeline executes a fraction of the raw bytes, faster.
#[test]
fn partial_reversal_churn_saves_bytes_and_makespan() {
    assert_churn_savings("partial-reversal", 7, |len| len / 4);
}

/// The whole scenario library, pipeline on vs off: identical final
/// balance, never more executed bytes than planned — on all 7
/// scenarios (the CI plan-smoke contract).
#[test]
fn library_scenarios_execute_within_raw_budget() {
    for name in equilibrium::scenario::ALL {
        let run = |plan: PlanConfig| {
            let mut case = equilibrium::scenario::library::by_name(name, 5, true).unwrap();
            case.config.plan = plan;
            let out = case.run().unwrap_or_else(|e| panic!("{name}: {e}"));
            (case, out)
        };
        let (case_raw, _) = run(PlanConfig::default());
        let (case_opt, out) = run(PlanConfig::phased());

        assert_eq!(
            case_raw.state.utilizations(),
            case_opt.state.utilizations(),
            "{name}: the pipeline must not change the final balance"
        );
        assert!(
            out.plan.bytes <= out.plan.raw_bytes,
            "{name}: executed {} > planned {}",
            out.plan.bytes,
            out.plan.raw_bytes
        );
        assert_eq!(out.plan.fallbacks, 0, "{name}: balancer plans never fall back");
        assert!(case_opt.state.verify().is_empty(), "{name}: invariants violated");
    }
}
