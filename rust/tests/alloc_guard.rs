//! Allocation guard (RFC 0006): the arena's hot-path lookups —
//! `pool_rank` (sorted-Vec binary search), `pg_idx`, and the column
//! reads behind `pg_at` — must be allocation-free. A `HashMap`/`BTreeMap`
//! rank table or a per-view `Vec` would show up here as a count.
//!
//! This file installs a counting `#[global_allocator]`, so it holds
//! exactly ONE test: libtest runs tests in threads, and a sibling test
//! allocating concurrently would make the count racy. Keep it that way.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use equilibrium::cluster::PgId;
use equilibrium::generator::clusters;
use equilibrium::util::bench::black_box;

/// System allocator with a global allocation counter.
struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn arena_lookups_do_not_allocate() {
    let state = clusters::demo(7);
    // pre-collect the identities outside the measured section
    let ids: Vec<PgId> = state.pgs().map(|v| v.id()).collect();
    assert!(!ids.is_empty());

    let before = ALLOCATIONS.load(Ordering::SeqCst);
    let mut acc = 0u64;
    for _ in 0..50 {
        for &id in &ids {
            // pool_rank binary search + dense offset arithmetic
            let idx = state.pg_idx(id).expect("known PG");
            // O(1) column reads off the same index
            acc = acc.wrapping_add(state.shard_bytes_at(idx));
            let view = state.pg_at(idx);
            for slot in 0..view.acting().len() {
                if let Some(osd) = view.acting_osd(slot) {
                    acc = acc.wrapping_add(osd as u64);
                }
            }
        }
    }
    black_box(acc);
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "arena lookups allocated {} times across {} lookups — the rank \
         table or view path regressed off the alloc-free contract",
        after - before,
        50 * ids.len()
    );
}
