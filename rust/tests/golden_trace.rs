//! Golden-trace equivalence: the incremental engine must emit **exactly**
//! the movement sequence of the pre-refactor full-sort loop
//! (`ReferenceEquilibrium`), move for move, on the paper's Table 1
//! synthetic clusters and on randomized clusters — including after
//! device failures and under interleaved client writes.
//!
//! This is the refactor's correctness contract (RFC 0001): the engine
//! may only change *how fast* a move is found, never *which* move.

use equilibrium::balancer::upmap_script::diff_plan;
use equilibrium::balancer::{Balancer, Equilibrium, ReferenceEquilibrium};
use equilibrium::cluster::{ClusterState, Movement, PgId};
use equilibrium::crush::OsdId;
use equilibrium::generator::clusters;
use equilibrium::generator::synth::random_cluster;
use equilibrium::plan::{net_relocations, optimize_plan, schedule_plan, ScheduleConfig};
use equilibrium::simulator::{Workload, WorkloadModel};
use equilibrium::util::parallel;
use equilibrium::util::prop::check_seeded;

type Trace = Vec<(PgId, OsdId, OsdId, u64)>;

/// Drive the reference loop, applying each move; the resulting sequence
/// is the specification.
fn reference_trace(initial: &ClusterState, cap: usize) -> Trace {
    let mut state = initial.clone();
    let mut bal = ReferenceEquilibrium::default();
    let mut out = Trace::new();
    while out.len() < cap {
        let Some(p) = bal.next_move(&state) else { break };
        state.apply_movement(p.pg, p.from, p.to).unwrap();
        out.push((p.pg, p.from, p.to, p.bytes));
    }
    out
}

/// Drive the incremental engine one move at a time via `next_move`.
fn stepwise_trace(initial: &ClusterState, cap: usize) -> Trace {
    let mut state = initial.clone();
    let mut bal = Equilibrium::default();
    let mut out = Trace::new();
    while out.len() < cap {
        let Some(p) = bal.next_move(&state) else { break };
        state.apply_movement(p.pg, p.from, p.to).unwrap();
        out.push((p.pg, p.from, p.to, p.bytes));
    }
    assert!(state.verify().is_empty(), "engine state invariants violated");
    out
}

/// Drive the incremental engine through `propose_batch` in chunks.
fn batched_trace(initial: &ClusterState, cap: usize, chunk: usize) -> Trace {
    let mut state = initial.clone();
    let mut bal = Equilibrium::default();
    let mut out = Trace::new();
    while out.len() < cap {
        let budget = chunk.min(cap - out.len());
        let batch = bal.propose_batch(&mut state, budget);
        let converged = batch.len() < budget;
        out.extend(batch.into_iter().map(|m| (m.pg, m.from, m.to, m.bytes)));
        if converged {
            break;
        }
    }
    assert!(state.verify().is_empty(), "batched state invariants violated");
    out
}

fn assert_traces_equal(label: &str, expect: &Trace, got: &Trace) {
    for (i, (a, b)) in expect.iter().zip(got).enumerate() {
        assert_eq!(a, b, "{label}: traces diverge at move {i}");
    }
    assert_eq!(
        expect.len(),
        got.len(),
        "{label}: one engine converged early ({} vs {} moves)",
        expect.len(),
        got.len()
    );
}

fn assert_golden(label: &str, initial: &ClusterState, cap: usize) {
    let expect = reference_trace(initial, cap);
    assert_traces_equal(label, &expect, &stepwise_trace(initial, cap));
    // batching must not change the sequence either, for any chunking
    assert_traces_equal(
        &format!("{label} (batched)"),
        &expect,
        &batched_trace(initial, cap, 37),
    );
}

/// Cluster A (Table 1): full run to convergence.
#[test]
fn golden_trace_cluster_a_full() {
    let c = clusters::by_name("a", 0).unwrap();
    assert_golden("cluster A", &c.state, 10_000);
}

/// Cluster F (Table 1): full run to convergence.
#[test]
fn golden_trace_cluster_f_full() {
    let c = clusters::by_name("f", 0).unwrap();
    assert_golden("cluster F", &c.state, 10_000);
}

/// Cluster C (Table 1): first 300 moves (full convergence is covered by
/// the integration suite; the prefix pins per-move identity cheaply).
#[test]
fn golden_trace_cluster_c_prefix() {
    let c = clusters::by_name("c", 0).unwrap();
    assert_golden("cluster C", &c.state, 300);
}

/// Randomized clusters: shapes the Table 1 set does not cover
/// (EC-only, tiny, heterogeneous pools).
#[test]
fn golden_trace_random_clusters() {
    check_seeded("golden-random", 0x60_1D, 8, |rng| {
        let state = random_cluster(rng);
        let expect = reference_trace(&state, 400);
        let step = stepwise_trace(&state, 400);
        let batch = batched_trace(&state, 400, 11);
        if expect != step {
            return Err(format!("stepwise divergence ({} vs {} moves)", expect.len(), step.len()));
        }
        if expect != batch {
            return Err(format!("batched divergence ({} vs {} moves)", expect.len(), batch.len()));
        }
        Ok(())
    });
}

/// Pin the plan pipeline's output alongside a raw trace: the optimized
/// move sequence is deterministic, matches an independent upmap-table
/// diff oracle as a set, reaches the identical final state within the
/// raw budget, and its phase assignment is byte-identical across
/// thread counts.
fn assert_optimized_pinned(label: &str, initial: &ClusterState, cap: usize) {
    let mut state = initial.clone();
    let mut bal = Equilibrium::default();
    let raw = bal.propose_batch(&mut state, cap);
    assert!(!raw.is_empty(), "{label}: cluster must need balancing");

    let opt = optimize_plan(initial, &raw);
    assert!(!opt.stats.fell_back, "{label}: balancer plans never fall back");
    assert!(opt.movements.len() <= raw.len(), "{label}: move budget");
    assert!(opt.stats.bytes <= opt.stats.raw_bytes, "{label}: byte budget");

    // determinism pin: re-optimizing emits the identical sequence
    let again = optimize_plan(initial, &raw);
    assert_eq!(
        opt.movements.len(),
        again.movements.len(),
        "{label}: optimizer sequence unstable"
    );
    for (i, (a, b)) in opt.movements.iter().zip(&again.movements).enumerate() {
        assert_eq!(
            (a.pg, a.from, a.to, a.bytes),
            (b.pg, b.from, b.to, b.bytes),
            "{label}: optimizer diverges at move {i}"
        );
    }

    // independent oracle: the optimized plan's net relocations equal
    // the upmap-table diff of the raw plan's final state (a separate,
    // table-based derivation). Folding to nets keeps the pin valid even
    // if the optimizer ever realizes a slot-swap cycle via an
    // intermediate hop.
    let key = |m: &Movement| (m.pg, m.from, m.to, m.bytes);
    let net = diff_plan(initial, &state.upmap_table()).unwrap();
    let mut want: Vec<_> = net.iter().map(key).collect();
    want.sort(); // diff is already one net move per slot — no folding
    let mut got: Vec<_> = net_relocations(&opt.movements).iter().map(key).collect();
    got.sort();
    assert_eq!(want, got, "{label}: optimizer disagrees with the table-diff oracle");

    // identical final state when replayed
    let mut replay = initial.clone();
    for m in &opt.movements {
        replay.apply_movement(m.pg, m.from, m.to).unwrap();
    }
    assert_eq!(replay.upmap_table(), state.upmap_table(), "{label}: upmap differs");
    for o in 0..initial.osd_count() as OsdId {
        assert_eq!(replay.osd_used(o), state.osd_used(o), "{label}: osd.{o} differs");
    }

    // phase assignment: a pure function of the plan, pinned across
    // thread counts like every other artifact in this suite
    let phases = |threads: usize| -> Vec<Vec<(PgId, OsdId, OsdId)>> {
        parallel::with_threads(threads, || {
            schedule_plan(initial, &opt.movements, &ScheduleConfig::default())
                .phases
                .iter()
                .map(|p| p.iter().map(|m| (m.pg, m.from, m.to)).collect())
                .collect()
        })
    };
    let p1 = phases(1);
    let p4 = phases(4);
    assert_eq!(p1, p4, "{label}: phase assignment diverges across thread counts");
    assert_eq!(
        p1.iter().map(Vec::len).sum::<usize>(),
        opt.movements.len(),
        "{label}: schedule must place every optimized move"
    );
}

/// Cluster A (Table 1): optimized plan + phases pinned on the full run.
#[test]
fn optimized_trace_cluster_a_full() {
    let c = clusters::by_name("a", 0).unwrap();
    assert_optimized_pinned("cluster A optimized", &c.state, 10_000);
}

/// Cluster C (Table 1): optimized plan + phases pinned on the 300-move
/// prefix (mirrors the raw-plan prefix pin above).
#[test]
fn optimized_trace_cluster_c_prefix() {
    let c = clusters::by_name("c", 0).unwrap();
    assert_optimized_pinned("cluster C optimized", &c.state, 300);
}

/// After a device failure the ideal-count caches shift (the failed
/// device's weight is zeroed); both engines must keep agreeing.
#[test]
fn golden_trace_after_failure() {
    let mut state = clusters::demo(29);
    equilibrium::cluster::fail_osd(&mut state, 4);
    assert!(state.verify().is_empty());
    assert_golden("demo after failure", &state, 10_000);
}

/// Interleaved client writes between selections: the engine's persistent
/// caches must observe every external mutation (they live in
/// ClusterState, so this exercises the incremental maintenance).
#[test]
fn golden_trace_under_interleaved_writes() {
    let initial = clusters::demo(31);

    let mut s_ref = initial.clone();
    let mut s_inc = initial.clone();
    let mut reference = ReferenceEquilibrium::default();
    let mut engine = Equilibrium::default();
    // identical write streams on both states
    let mut w_ref = Workload::new(WorkloadModel::Uniform, 0xBEEF);
    let mut w_inc = Workload::new(WorkloadModel::Uniform, 0xBEEF);

    let mut moves = 0;
    for round in 0..30 {
        let a = reference.next_move(&s_ref);
        let b = engine.next_move(&s_inc);
        match (a, b) {
            (None, None) => {}
            (Some(pa), Some(pb)) => {
                assert_eq!(
                    (pa.pg, pa.from, pa.to, pa.bytes),
                    (pb.pg, pb.from, pb.to, pb.bytes),
                    "divergence at move {moves} (round {round})"
                );
                s_ref.apply_movement(pa.pg, pa.from, pa.to).unwrap();
                s_inc.apply_movement(pb.pg, pb.from, pb.to).unwrap();
                moves += 1;
            }
            (a, b) => panic!("round {round}: engines disagree on convergence: {a:?} vs {b:?}"),
        }
        let wrote_ref = w_ref.write(&mut s_ref, 8 << 30);
        let wrote_inc = w_inc.write(&mut s_inc, 8 << 30);
        assert_eq!(wrote_ref, wrote_inc, "write streams must match");
    }
    assert!(moves > 0, "write-perturbed demo cluster must offer moves");
    assert!(s_inc.verify().is_empty());
}
