//! Estate coordinator integration tests (RFC 0008): thread-count
//! determinism of estate sweeps, the health-weighted routing win over
//! round-robin on a capacity-skewed estate, and degraded-member pool
//! migration end to end.

use equilibrium::estate::{
    library, sweep_spec, Estate, EstateConfig, EstateSweepConfig, HealthWeighted, RoundRobin,
};
use equilibrium::util::parallel::with_threads;

fn smoke_sweep(case: &str, router: &str) -> String {
    let case = library::by_name(case, 0, true).expect("library case");
    let cfg = EstateSweepConfig::smoke();
    sweep_spec(&case.spec, router, &case.config, &cfg)
        .expect("sweep")
        .summarize(cfg.seed_base)
        .render()
}

#[test]
fn estate_sweep_is_byte_identical_across_thread_counts() {
    for name in library::ALL {
        let one = with_threads(1, || smoke_sweep(name, "health"));
        let four = with_threads(4, || smoke_sweep(name, "health"));
        assert_eq!(one, four, "estate case '{name}' diverged between 1 and 4 threads");
    }
}

#[test]
fn health_routing_beats_round_robin_on_a_skewed_estate() {
    // the headline claim, smoke-sized: over the sweep, health-weighted
    // routing ends with strictly lower cross-cluster utilization
    // variance than the round-robin baseline (benches/estate.rs gates
    // the full-size version)
    let case = library::by_name("routed-growth", 0, true).unwrap();
    let cfg = EstateSweepConfig::smoke();
    let dist = |router: &str| {
        sweep_spec(&case.spec, router, &case.config, &cfg)
            .expect("sweep")
            .summarize(cfg.seed_base)
            .metrics["estate_variance"]
    };
    let health = dist("health");
    let rr = dist("round-robin");
    assert!(
        health.mean < rr.mean,
        "health-weighted mean estate variance {} must beat round-robin {}",
        health.mean,
        rr.mean,
    );
}

#[test]
fn degraded_failover_case_migrates_and_survives() {
    let case = library::by_name("degraded-failover", 3, true).unwrap();
    let estate = Estate::from_spec(&case.spec, Box::new(HealthWeighted), case.config.clone())
        .expect("estate builds");
    let out = estate.run(&case.spec).expect("timeline runs");
    // the failed member crossed the threshold and was drained: whether
    // pools lived there depends on routing, but health reporting must
    // flag the degradation either way
    assert!(
        out.healths.iter().any(|h| h.degraded),
        "the failover case must leave a degraded member"
    );
    assert!(out.samples.len() >= 3, "initial, pre-failure, and final snapshots");
    assert!(out.elapsed > 0.0);
    // member makespans feed the estate metrics; every channel finite
    assert!(out.member_makespans.iter().all(|m| m.is_finite()));
}

#[test]
fn round_robin_spreads_pools_where_health_concentrates_headroom() {
    let case = library::by_name("routed-growth", 1, true).unwrap();
    let run = |router: Box<dyn equilibrium::estate::Router>| {
        Estate::from_spec(&case.spec, router, case.config.clone())
            .expect("estate builds")
            .run(&case.spec)
            .expect("runs")
    };
    let health = run(Box::new(HealthWeighted));
    let rr = run(Box::new(RoundRobin::default()));
    // same timeline, same seed, different placement: the routers must
    // actually disagree — otherwise the comparison tests above are
    // vacuous
    let hu = &health.samples.last().unwrap().member_utilization;
    let ru = &rr.samples.last().unwrap().member_utilization;
    assert_ne!(hu, ru, "routers placed identically; the estate comparison is vacuous");
    assert!(health.estate_variance < rr.estate_variance);
}

#[test]
fn mixed_churn_stays_quiet_on_migrations() {
    let case = library::by_name("mixed-churn", 2, true).unwrap();
    let estate = Estate::from_spec(&case.spec, Box::new(HealthWeighted), case.config.clone())
        .expect("estate builds");
    let out = estate.run(&case.spec).expect("timeline runs");
    // the single-device failure stays under the degraded threshold, so
    // the health checks must not migrate anything
    assert_eq!(out.migrations, 0, "sub-threshold failure must not trigger migration");
    assert_eq!(out.migrated_bytes, 0);
    assert!(out.healths.iter().all(|h| !h.degraded));
    assert!(out.executed_bytes > 0, "balance rounds must execute data movement");
}
