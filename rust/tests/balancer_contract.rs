//! Conformance suite for every pluggable [`Balancer`] implementation.
//!
//! The bake-off (`fleet compare --balancers`) treats engines as
//! interchangeable plugins; this suite pins the contract that makes
//! that safe, for **all** registry balancers at once:
//!
//! * every proposal is CRUSH-legal against the state it was made for;
//! * `propose_batch(max)` never exceeds `max`;
//! * a converged balancer proposes nothing — and stays silent when
//!   asked again;
//! * after a topology change (`add_hosts` + `fail_osd`) and
//!   `on_topology_change`, no proposal ever references a stale or
//!   non-indexed OSD;
//! * the move sequence is byte-identical at `EQUILIBRIUM_THREADS=1`
//!   and `=4`.
//!
//! A new engine added to [`fleet::compare::make_balancer`] is covered
//! automatically: the suite iterates the registry, not a local list.

use equilibrium::balancer::constraints::check_move;
use equilibrium::balancer::Balancer;
use equilibrium::cluster::{add_hosts, fail_osd, HostSpec, Pool};
use equilibrium::crush::{CrushBuilder, DeviceClass, Level, OsdId, Rule};
use equilibrium::fleet::{make_balancer, BALANCERS};
use equilibrium::generator::clusters;
use equilibrium::util::parallel::with_threads;
use equilibrium::util::units::{GIB, TIB};

/// A small imbalanced cluster every engine can act on: 6 hosts × 2
/// OSDs, one 3-replica pool with skewed shard sizes.
fn cluster() -> equilibrium::cluster::ClusterState {
    let mut b = CrushBuilder::new();
    let root = b.add_root("default");
    for h in 0..6 {
        let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
        for _ in 0..2 {
            b.add_osd_bytes(host, 4 * TIB, DeviceClass::Hdd);
        }
    }
    b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
    equilibrium::cluster::ClusterState::build(
        b.build().unwrap(),
        vec![Pool::replicated(1, "data", 3, 64, 0)],
        |_, i| (5 + (i % 9) as u64) * GIB,
    )
}

/// Every engine in the bake-off registry, fresh.
fn registry() -> Vec<Box<dyn Balancer>> {
    BALANCERS
        .iter()
        .map(|name| make_balancer(name).expect("registry constructs its own names"))
        .collect()
}

#[test]
fn every_proposal_is_crush_legal() {
    for mut bal in registry() {
        let mut state = cluster();
        bal.on_round_start(&state);
        let mut steps = 0;
        while let Some(p) = bal.next_move(&state) {
            check_move(&state, p.pg, p.from, p.to).unwrap_or_else(|v| {
                panic!("balancer '{}' proposed illegal move {:?}: {v:?}", bal.name(), p)
            });
            assert_eq!(
                p.bytes,
                state.pg(p.pg).unwrap().shard_bytes(),
                "balancer '{}' mis-stated shard size",
                bal.name()
            );
            state.apply_movement(p.pg, p.from, p.to).unwrap();
            steps += 1;
            assert!(steps <= 10_000, "balancer '{}' failed to terminate", bal.name());
        }
        assert!(state.verify().is_empty(), "balancer '{}' broke invariants", bal.name());
    }
}

#[test]
fn propose_batch_respects_the_cap() {
    for mut bal in registry() {
        let mut state = cluster();
        bal.on_round_start(&state);
        let moves = bal.propose_batch(&mut state, 3);
        assert!(moves.len() <= 3, "balancer '{}' exceeded max_moves", bal.name());
    }
}

#[test]
fn converged_balancers_stay_silent() {
    for mut bal in registry() {
        let mut state = cluster();
        // drive to convergence under round framing (bounded engines
        // need fresh budgets per round to reach the fixpoint)
        let mut rounds = 0;
        loop {
            bal.on_round_start(&state);
            if bal.propose_batch(&mut state, 10_000).is_empty() {
                break;
            }
            rounds += 1;
            assert!(rounds <= 10_000, "balancer '{}' never converged", bal.name());
        }
        // silence must be stable, with and without a fresh round
        assert!(bal.next_move(&state).is_none(), "balancer '{}' spoke after convergence", bal.name());
        bal.on_round_start(&state);
        assert!(bal.next_move(&state).is_none(), "balancer '{}' spoke after convergence", bal.name());
    }
}

#[test]
fn topology_changes_never_yield_stale_osds() {
    for mut bal in registry() {
        let mut state = cluster();
        // warm the engine's caches on the original map
        bal.on_round_start(&state);
        let _ = bal.propose_batch(&mut state, 5);

        // structural change: two new hosts come up, one device fails out
        add_hosts(&mut state, &HostSpec::hdd(2, 2, 4 * TIB)).unwrap();
        fail_osd(&mut state, 3);
        bal.on_topology_change();

        bal.on_round_start(&state);
        let mut steps = 0;
        while let Some(p) = bal.next_move(&state) {
            assert!(
                state.osd_is_indexed(p.to),
                "balancer '{}' targeted stale/non-indexed osd.{}",
                bal.name(),
                p.to
            );
            assert_ne!(p.to, 3, "balancer '{}' targeted the failed device", bal.name());
            assert!(
                (p.to as usize) < state.osd_count() && (p.from as usize) < state.osd_count(),
                "balancer '{}' referenced an out-of-range osd",
                bal.name()
            );
            check_move(&state, p.pg, p.from, p.to).unwrap_or_else(|v| {
                panic!("balancer '{}' proposed illegal move {:?}: {v:?}", bal.name(), p)
            });
            state.apply_movement(p.pg, p.from, p.to).unwrap();
            steps += 1;
            if steps >= 2_000 {
                break; // legality is the contract here, not convergence speed
            }
        }
        assert!(state.verify().is_empty(), "balancer '{}' broke invariants", bal.name());
    }
}

#[test]
fn move_sequences_are_thread_count_independent() {
    for name in BALANCERS {
        let sequence = |threads: usize| {
            with_threads(threads, || {
                let mut bal = make_balancer(name).unwrap();
                let mut state = clusters::demo(42);
                bal.on_round_start(&state);
                bal.propose_batch(&mut state, 200)
                    .into_iter()
                    .map(|m| (m.pg, m.from, m.to, m.bytes))
                    .collect::<Vec<(equilibrium::cluster::PgId, OsdId, OsdId, u64)>>()
            })
        };
        let single = sequence(1);
        let multi = sequence(4);
        assert_eq!(single, multi, "balancer '{name}' diverges across thread counts");
    }
}
