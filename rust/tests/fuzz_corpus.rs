//! Replay the promoted fuzz regression corpus (`corpus/regressions/`).
//!
//! Every spec the fuzzer ever minimized and promoted is replayed here
//! under the standard invariant suite forever after. A spec in the
//! corpus is *expected to pass now*: promotion happens when a violation
//! is found, the underlying bug gets fixed, and the spec stays behind
//! as a pinned regression test. A failing replay therefore means a
//! previously-fixed bug is back (or a promoted spec was committed
//! without its fix — see `corpus/README.md`).

use std::path::PathBuf;

use equilibrium::fuzz::replay;
use equilibrium::scenario::serde;

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("corpus").join("regressions")
}

/// Sorted spec paths, so the replay order (and any failure output) is
/// stable across filesystems.
fn corpus_specs() -> Vec<PathBuf> {
    let Ok(entries) = std::fs::read_dir(corpus_dir()) else {
        return Vec::new(); // no corpus yet — vacuously green
    };
    let mut specs: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    specs.sort();
    specs
}

#[test]
fn every_promoted_regression_replays_clean() {
    let mut failures = Vec::new();
    for path in corpus_specs() {
        let spec = match serde::load_file(&path) {
            Ok(spec) => spec,
            Err(e) => {
                failures.push(format!("{}: does not load: {e}", path.display()));
                continue;
            }
        };
        let outcome = replay(&spec);
        if let Some(err) = &outcome.error {
            failures.push(format!("{}: engine error: {err}", path.display()));
        }
        for v in &outcome.violations {
            failures.push(format!("{}: {v}", path.display()));
        }
    }
    assert!(
        failures.is_empty(),
        "{} corpus regression(s) failing:\n  {}",
        failures.len(),
        failures.join("\n  ")
    );
}

#[test]
fn corpus_files_are_canonically_formatted() {
    // promoted specs are exactly `serde::dump` output, so diffs stay
    // reviewable and replays are byte-reproducible
    for path in corpus_specs() {
        let text = std::fs::read_to_string(&path).expect("corpus file readable");
        let spec = serde::load_file(&path).expect("corpus file loads");
        assert_eq!(
            serde::dump(&spec),
            text,
            "{} is not canonical serde::dump output",
            path.display()
        );
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or_default();
        assert_eq!(spec.name, stem, "{}: spec name must match file stem", path.display());
    }
}
