//! Cross-format snapshot properties (RFC 0007).
//!
//! The binary `.eqsnap` format and the JSON dump must describe the same
//! state class: on any cluster either format can produce, loading
//! through one and re-serializing through the other is the identity.
//! Exercised on the paper clusters, on fuzz-generated timelines (one
//! per weight profile), and on the hyperscale smoke tier; plus
//! corruption robustness (every failure is a typed `SnapshotError`)
//! and memory-footprint accounting for the codec buffers.

use equilibrium::balancer::Equilibrium;
use equilibrium::cluster::{dump, snapshot, ClusterState, SnapshotError};
use equilibrium::fuzz::{generate_spec, Profile};
use equilibrium::generator::{clusters, hyperscale};
use equilibrium::scenario::{ScenarioConfig, ScenarioEngine};
use equilibrium::util::codec::ByteWriter;
use equilibrium::util::mem::MemoryFootprint;

/// Both round trips, both formats: `decode(encode(s))` must dump the
/// same JSON as `s`, and `load(dump(s))` must encode the same bytes as
/// `s`. Equal dumps ⇒ equal states (the dump is canonical), and equal
/// encodings ⇒ equal states (the encoder is deterministic).
fn assert_cross_format_identity(s: &ClusterState, label: &str) {
    let bin = snapshot::encode(s);
    let decoded = snapshot::decode(&bin).unwrap_or_else(|e| panic!("{label}: decode: {e}"));
    assert!(decoded.verify().is_empty(), "{label}: decoded state verifies");
    assert_eq!(dump::dump(&decoded), dump::dump(s), "{label}: binary→JSON identity");

    let json_state =
        dump::load(&dump::dump(s)).unwrap_or_else(|e| panic!("{label}: json load: {e}"));
    assert_eq!(snapshot::encode(&json_state), bin, "{label}: JSON→binary identity");
}

#[test]
fn paper_clusters_round_trip_across_both_formats() {
    for name in ["a", "c", "f"] {
        let s = clusters::by_name(name, 7).expect("paper cluster").state;
        assert_cross_format_identity(&s, &format!("cluster {name}"));
    }
}

#[test]
fn fuzz_generated_timelines_round_trip_and_keep_osd_state() {
    for (i, &profile) in Profile::ALL.iter().enumerate() {
        let seed = 0x5AB5_0000 + i as u64;
        let base = clusters::demo(seed);
        let spec = generate_spec(&base, seed, profile, true);
        let mut state = base;
        let mut balancer = Equilibrium::default();
        let config = ScenarioConfig { record_series: false, ..ScenarioConfig::default() };
        let engine = ScenarioEngine::new(&mut state, Some(&mut balancer), config, spec.seed);
        // some generated timelines legitimately abort (e.g. no balancer
        // progress) — whatever state they leave behind must still snapshot
        let _ = engine.run(&spec);

        let label = format!("profile {profile:?}");
        let bin = snapshot::encode(&state);
        let decoded = snapshot::decode(&bin).unwrap_or_else(|e| panic!("{label}: decode: {e}"));
        assert_eq!(dump::dump(&decoded), dump::dump(&state), "{label}: dump identity");
        // what JSON cannot carry, the binary must: up/down and capacities
        for o in 0..state.osd_count() as u32 {
            assert_eq!(decoded.osd_is_up(o), state.osd_is_up(o), "{label}: osd.{o} up state");
            assert_eq!(decoded.osd_size(o), state.osd_size(o), "{label}: osd.{o} capacity");
        }
    }
}

#[test]
fn hyperscale_smoke_tier_round_trips() {
    let s = hyperscale::build(&hyperscale::SMOKE, 0xD47AC);
    assert_cross_format_identity(&s, "hyperscale smoke tier");
}

#[test]
fn corrupted_snapshots_are_typed_errors_never_panics() {
    let s = clusters::demo(3);
    let bytes = snapshot::encode(&s);

    // bad magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    assert!(matches!(snapshot::decode(&bad), Err(SnapshotError::Magic)));

    // unknown version
    let mut bad = bytes.clone();
    bad[6] = 0xFE;
    bad[7] = 0xCA;
    assert!(matches!(snapshot::decode(&bad), Err(SnapshotError::Version(_))));

    // every truncation point decodes to an error, not a panic
    for keep in 0..bytes.len().min(160) {
        assert!(snapshot::decode(&bytes[..keep]).is_err(), "truncated to {keep}");
    }
    for keep in (160..bytes.len()).step_by(61) {
        assert!(snapshot::decode(&bytes[..keep]).is_err(), "truncated to {keep}");
    }

    // a flipped byte anywhere past the version field fails the digest
    // (or, for the version bytes themselves, the version check)
    for at in (8..bytes.len()).step_by(97) {
        let mut bad = bytes.clone();
        bad[at] ^= 0x10;
        match snapshot::decode(&bad) {
            Err(_) => {}
            Ok(_) => panic!("flipping byte {at} went unnoticed"),
        }
    }
    // flipping a digest byte specifically reports the digest mismatch
    let mut bad = bytes.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x01;
    assert!(matches!(snapshot::decode(&bad), Err(SnapshotError::Digest { .. })));
}

#[test]
fn encode_buffer_is_presized_and_accounted() {
    let s = clusters::demo(11);
    let bytes = snapshot::encode(&s);
    let estimate = snapshot::encoded_size_estimate(&s);
    assert!(
        estimate >= bytes.len(),
        "estimate {estimate} must upper-bound the encoding ({} bytes)",
        bytes.len()
    );
    assert!(
        estimate <= bytes.len() * 4,
        "estimate {estimate} is wastefully loose for {} bytes",
        bytes.len()
    );

    // the codec buffer reports its footprint by capacity, so a
    // pre-sized writer accounts at least every byte it will hold
    let mut w = ByteWriter::with_capacity(estimate);
    w.put_bytes(&bytes);
    assert!(w.heap_bytes() >= bytes.len());
    assert!(w.heap_bytes() >= estimate, "with_capacity is fully accounted");
}

#[test]
fn decoded_state_is_as_compact_as_the_original() {
    let s = clusters::demo(5);
    let decoded = snapshot::decode(&snapshot::encode(&s)).unwrap();
    // bulk column reads must not leave oversized buffers behind: the
    // decoded arena's accounted heap matches a freshly built state's
    assert_eq!(decoded.arena_bytes(), s.arena_bytes());
}
