//! Cross-layer integration of the scenario engine: adapter equivalence
//! with the pre-refactor drivers, library end-to-end runs, and
//! figures-compatible output.

use equilibrium::balancer::{Balancer, Equilibrium, MgrBalancer};
use equilibrium::generator::clusters;
use equilibrium::report;
use equilibrium::scenario::{
    library, ScenarioConfig, ScenarioEngine, ScenarioEvent, ScenarioSpec,
};
use equilibrium::simulator::{simulate, SimOptions, WorkloadModel};
use equilibrium::util::units::{GIB, TIB};

/// Pure-balancing scenarios must reproduce the historical select/apply
/// sequence for *any* balancer — the acceptance contract of the
/// refactor. Covers both the incremental engine and the mgr baseline on
/// a paper cluster.
#[test]
fn scenario_balance_round_matches_manual_loop_on_cluster_a() {
    let initial = clusters::by_name("a", 0).unwrap().state;

    for which in ["equilibrium", "mgr"] {
        let make = || -> Box<dyn Balancer> {
            match which {
                "equilibrium" => Box::new(Equilibrium::default()),
                _ => Box::new(MgrBalancer::default()),
            }
        };

        let mut manual_state = initial.clone();
        let mut manual_bal = make();
        let mut manual = Vec::new();
        while manual.len() < 600 {
            let Some(p) = manual_bal.next_move(&manual_state) else { break };
            manual.push(manual_state.apply_movement(p.pg, p.from, p.to).unwrap());
        }

        let mut state = initial.clone();
        let mut bal = make();
        let res = simulate(
            bal.as_mut(),
            &mut state,
            &SimOptions { max_moves: 600, sample_every: 7, ..SimOptions::default() },
        );
        assert_eq!(res.movements.len(), manual.len(), "{which}: lengths differ");
        for (i, (a, b)) in res.movements.iter().zip(&manual).enumerate() {
            assert_eq!(
                (a.pg, a.from, a.to, a.bytes),
                (b.pg, b.from, b.to, b.bytes),
                "{which}: diverged at move {i}"
            );
        }
    }
}

/// The whole library runs end to end in reduced mode, is seed-stable,
/// and leaves the cluster invariant-clean.
#[test]
fn scenario_library_reduced_end_to_end() {
    for name in equilibrium::scenario::ALL {
        let mut case = library::by_name(name, 1, true).unwrap();
        let out = case.run().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(case.state.verify().is_empty(), "{name}: {:?}", case.state.verify());
        assert!(out.series.samples.len() >= 2, "{name}");
        // every sample's virtual timestamp is monotone non-decreasing
        let mut last = 0.0;
        for s in &out.series.samples {
            assert!(s.vtime + 1e-12 >= last, "{name}: vtime went backwards");
            last = s.vtime;
        }
    }
}

/// Compound scenarios change the topology as declared.
#[test]
fn compound_scenarios_change_topology_as_declared() {
    let mut rolling = library::by_name("rolling-expansion", 2, true).unwrap();
    let osds_before = rolling.state.osd_count();
    rolling.run().unwrap();
    assert_eq!(rolling.state.osd_count(), osds_before + 6, "3 hosts × 2 OSDs arrive");

    let mut failure = library::by_name("device-failure", 2, true).unwrap();
    failure.run().unwrap();
    assert!(!failure.state.osd_is_up(3), "the failed device stays out");

    let mut decom = library::by_name("pool-decommission", 2, true).unwrap();
    decom.run().unwrap();
    let scratch_bytes: u64 = decom
        .state
        .pgs_of_pool(50)
        .map(|p| p.shard_bytes())
        .sum();
    assert_eq!(scratch_bytes, 0, "decommissioned pool is empty");
}

/// The unified series feeds report::figures' CSV channel.
#[test]
fn scenario_series_is_figures_consumable() {
    let mut case = library::by_name("rack-failure-under-hotspot", 4, true).unwrap();
    let out = case.run().unwrap();
    let dir = std::env::temp_dir().join("equilibrium_scenario_csv_test");
    let _ = std::fs::remove_dir_all(&dir);
    let path = report::scenario_series(&dir, case.name, &out.series).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let header = text.lines().next().unwrap();
    assert!(header.starts_with("moves,moved_bytes,calc_seconds,variance"));
    assert!(header.ends_with(",vtime"));
    assert!(text.lines().count() >= 3);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A hand-written compound timeline mixing every event family executes
/// deterministically and keeps all invariants.
#[test]
fn kitchen_sink_timeline_is_deterministic() {
    use equilibrium::cluster::{HostSpec, Pool};
    use equilibrium::generator::AgingConfig;

    let spec = ScenarioSpec::new("kitchen-sink", 77)
        .snapshot("start")
        .age(AgingConfig { epochs: 3, ..Default::default() })
        .balance(150)
        .create_pool(Pool::replicated(30, "burst", 3, 16, 0), 128 * GIB)
        .workload(WorkloadModel::Hotspot { pool: 30, fraction: 0.8 }, 32 * GIB, 900.0)
        .fail_osd(5)
        .balance(150)
        .add_hosts(HostSpec::hdd(1, 2, 8 * TIB))
        .balance(150)
        .shrink_pool(30, 64 * GIB)
        .decommission_pool(30)
        .balance(150)
        .snapshot("end");

    let run = |seed: u64| {
        let mut state = clusters::demo(seed);
        let mut bal = Equilibrium::default();
        let out = ScenarioEngine::new(
            &mut state,
            Some(&mut bal),
            ScenarioConfig::default(),
            spec.seed,
        )
        .run(&spec)
        .unwrap();
        assert!(state.verify().is_empty(), "{:?}", state.verify());
        (state.total_used(), out.movements.len(), out.elapsed)
    };
    let a = run(77);
    let b = run(77);
    assert_eq!(a, b, "same seed must replay bit-for-bit");
    assert!(a.2 > 0.0, "virtual time advanced");
}

/// Regression (RFC 0002): pools created *after* an expansion must keep
/// the dense pool-rank table and the per-OSD shard matrix consistent.
/// Expansion reassembles the state (ranks re-derived in pool-id order);
/// `add_pool` appends a rank — including one that is out of pool-id
/// order — and restrides the matrix, which must also cover the freshly
/// added OSDs. The pre-columnar state built its per-OSD counts lazily
/// per pool, so this interleaving was never layout-sensitive before.
#[test]
fn pool_created_after_expansion_keeps_dense_counts_consistent() {
    use equilibrium::cluster::{add_hosts, HostSpec, Pool};

    let mut s = clusters::demo(41); // pools {1, 2}
    let new_osds = add_hosts(&mut s, &HostSpec::hdd(2, 2, 8 * TIB)).unwrap();
    assert_eq!(new_osds.len(), 4);
    // one pool above the existing ids, one wedged between them: the
    // second append gives a rank order that differs from pool-id order
    s.add_pool(Pool::replicated(7, "after-high", 3, 16, 0), |_| GIB).unwrap();
    s.add_pool(Pool::replicated(3, "after-low", 3, 16, 0), |_| 2 * GIB).unwrap();
    assert!(s.verify().is_empty(), "{:?}", s.verify());

    // dense counts match a from-scratch recount for every pool,
    // including on the expansion's OSDs
    let recount = |s: &equilibrium::cluster::ClusterState, pool: u32, osd: u32| -> u32 {
        s.pgs_of_pool(pool).filter(|pg| pg.on(osd)).count() as u32
    };
    for &pool in &[1u32, 2, 3, 7] {
        for o in 0..s.osd_count() as u32 {
            assert_eq!(
                s.pool_shards_on(pool, o),
                recount(&s, pool, o),
                "pool {pool} count drift on osd.{o}"
            );
        }
    }

    // balancing across old and new pools keeps everything consistent
    // and lands data on the expansion
    let mut bal = Equilibrium::default();
    let moves = bal.propose_batch(&mut s, 300);
    assert!(!moves.is_empty());
    assert!(s.verify().is_empty(), "{:?}", s.verify());
    let landed: u64 = new_osds.iter().map(|&o| s.osd_used(o)).sum();
    assert!(landed > 0, "rebalancing must use the new hosts");

    // a dump round trip (ranks re-derived in id order) agrees with the
    // live state, upmap table included
    let loaded = equilibrium::cluster::dump::load(&equilibrium::cluster::dump::dump(&s)).unwrap();
    assert_eq!(loaded.utilizations(), s.utilizations());
    assert_eq!(loaded.upmap_table(), s.upmap_table());
    for &pool in &[1u32, 2, 3, 7] {
        for o in 0..s.osd_count() as u32 {
            assert_eq!(loaded.pool_shards_on(pool, o), s.pool_shards_on(pool, o));
        }
    }

    // the same interleaving through the scenario engine's events
    let mut state = clusters::demo(43);
    let mut bal = Equilibrium::default();
    let mut engine =
        ScenarioEngine::new(&mut state, Some(&mut bal), ScenarioConfig::default(), 43);
    engine
        .apply(&ScenarioEvent::AddHosts { spec: HostSpec::hdd(1, 2, 8 * TIB) })
        .unwrap();
    engine
        .apply(&ScenarioEvent::CreatePool {
            pool: Pool::replicated(9, "post-expansion", 3, 16, 0),
            user_bytes: 32 * GIB,
        })
        .unwrap();
    engine.apply(&ScenarioEvent::BalanceRound { max_moves: 100 }).unwrap();
    drop(engine);
    assert!(state.verify().is_empty(), "{:?}", state.verify());
    assert!(state.pool_shard_counts(9).is_some());
}

/// Edge cases the chaos fuzzer leans on, pinned individually: each has
/// a defined non-panicking outcome even though nothing sensible is left
/// to do.
#[test]
fn edge_case_events_have_pinned_outcomes() {
    use equilibrium::cluster::Pool;

    // -- ShrinkPool far past the pool's contents drains it to zero
    let mut state = clusters::demo(11);
    let mut bal = Equilibrium::default();
    let mut engine =
        ScenarioEngine::new(&mut state, Some(&mut bal), ScenarioConfig::silent(), 11);
    let out = engine.apply(&ScenarioEvent::ShrinkPool { pool: 1, user_bytes: u64::MAX / 4 }).unwrap();
    assert!(out.written_bytes > 0, "something was deleted");
    // a second over-shrink finds nothing left and is still not an error
    let out = engine.apply(&ScenarioEvent::ShrinkPool { pool: 1, user_bytes: u64::MAX / 4 }).unwrap();
    assert_eq!(out.written_bytes, 0, "pool already empty");
    drop(engine);
    let drained: u64 = state.pgs_of_pool(1).map(|pg| pg.shard_bytes()).sum();
    assert_eq!(drained, 0, "every PG of the pool is empty");
    assert!(state.verify().is_empty(), "{:?}", state.verify());

    // -- DecommissionPool works on every pool, including the last one:
    // pools drain (PGs stay mapped but hold zero bytes) and the cluster
    // stays consistent with nothing left to store
    let mut state = clusters::demo(12);
    let mut bal = Equilibrium::default();
    let mut engine =
        ScenarioEngine::new(&mut state, Some(&mut bal), ScenarioConfig::silent(), 12);
    let pool_ids: Vec<u32> = engine.state().pools.keys().copied().collect();
    for pool in pool_ids {
        engine.apply(&ScenarioEvent::DecommissionPool { pool }).unwrap();
    }
    // balancing an empty cluster must also be a graceful no-op
    let out = engine.apply(&ScenarioEvent::BalanceRound { max_moves: 100 }).unwrap();
    assert_eq!(out.executed_moves, 0, "nothing to balance after draining every pool");
    drop(engine);
    assert_eq!(state.total_used(), 0, "decommissioning every pool empties the cluster");
    assert!(state.verify().is_empty(), "{:?}", state.verify());

    // -- FailHost on an already-degraded host fails only the survivors,
    // and on a fully-dead host it is a defined no-op
    let mut state = clusters::demo(13);
    let host_osds: Vec<u32> = {
        let node = state.crush.bucket_by_name["host000"];
        state.crush.devices_under(node, None)
    };
    assert!(host_osds.len() >= 2, "demo hosts have two devices");
    let mut bal = Equilibrium::default();
    let mut engine =
        ScenarioEngine::new(&mut state, Some(&mut bal), ScenarioConfig::silent(), 13);
    engine.apply(&ScenarioEvent::FailOsd { osd: host_osds[0] }).unwrap();
    engine.apply(&ScenarioEvent::FailHost { host: "host000".into() }).unwrap();
    for &o in &host_osds {
        assert!(!engine.state().osd_is_up(o), "osd.{o} down after host failure");
    }
    // the host is fully dead now: failing it again must not error
    engine.apply(&ScenarioEvent::FailHost { host: "host000".into() }).unwrap();
    drop(engine);
    assert!(state.verify().is_empty(), "{:?}", state.verify());

    // -- BalanceRound with a zero move budget plans nothing, moves
    // nothing, and reports non-convergence rather than lying
    let mut state = clusters::demo(14);
    let var_before = state.utilization_variance();
    let mut bal = Equilibrium::default();
    let mut engine =
        ScenarioEngine::new(&mut state, Some(&mut bal), ScenarioConfig::silent(), 14);
    // grow a brand-new pool so there is real imbalance to (not) fix
    engine
        .apply(&ScenarioEvent::CreatePool {
            pool: Pool::replicated(5, "untouched", 3, 16, 0),
            user_bytes: 64 * GIB,
        })
        .unwrap();
    let out = engine.apply(&ScenarioEvent::BalanceRound { max_moves: 0 }).unwrap();
    assert_eq!(out.planned_moves, 0);
    assert_eq!(out.executed_moves, 0);
    assert_eq!(out.moved_bytes, 0);
    assert!(!out.converged, "a zero-budget round must not claim convergence");
    drop(engine);
    let _ = var_before;
    assert!(state.verify().is_empty(), "{:?}", state.verify());
}

/// Scenario events that reference missing entities fail loudly instead
/// of silently skipping.
#[test]
fn invalid_events_surface_errors() {
    let mut state = clusters::demo(3);
    let mut bal = Equilibrium::default();
    let mut engine =
        ScenarioEngine::new(&mut state, Some(&mut bal), ScenarioConfig::default(), 3);
    assert!(engine.apply(&ScenarioEvent::DecommissionPool { pool: 99 }).is_err());
    assert!(engine.apply(&ScenarioEvent::FailHost { host: "ghost".into() }).is_err());
    assert!(engine.apply(&ScenarioEvent::FailOsd { osd: 9999 }).is_err());
}
