//! Bitset-membership equivalence (RFC 0006): the packed [`BitSet`]
//! behind `ClusterState`'s up/down and indexed sets must be
//! indistinguishable from the plain `Vec<bool>` + linear-scan model it
//! replaced, under random up/down/fail/expand sequences on real
//! clusters.
//!
//! The raw container is pinned against `Vec<bool>` by its own unit
//! tests; this file pins the *cluster-level* accessors — `osd_is_up`,
//! `up_osd_count`, `up_osds`, `down_osds`, `osd_is_indexed` — which
//! route through incremental popcounts and the aggregates' mirror set
//! and could drift from the model independently of the container.

use equilibrium::cluster::expand::{add_hosts, HostSpec};
use equilibrium::cluster::recovery::fail_osd;
use equilibrium::cluster::ClusterState;
use equilibrium::crush::OsdId;
use equilibrium::generator::clusters;
use equilibrium::util::prop::check_seeded;
use equilibrium::util::rng::Rng;
use equilibrium::util::units::TIB;

/// Compare every membership accessor against the boolean model.
fn assert_matches_model(state: &ClusterState, model: &[bool]) -> Result<(), String> {
    if state.osd_count() != model.len() {
        return Err(format!("osd_count {} != model {}", state.osd_count(), model.len()));
    }
    let want_up: Vec<OsdId> = (0..model.len())
        .filter(|&o| model[o])
        .map(|o| o as OsdId)
        .collect();
    let want_down: Vec<OsdId> = (0..model.len())
        .filter(|&o| !model[o])
        .map(|o| o as OsdId)
        .collect();

    if state.up_osd_count() != want_up.len() {
        return Err(format!("up_osd_count {} != {}", state.up_osd_count(), want_up.len()));
    }
    let got_up: Vec<OsdId> = state.up_osds().collect();
    if got_up != want_up {
        return Err("up_osds() diverged from the Vec<bool> scan".into());
    }
    let got_down: Vec<OsdId> = state.down_osds().collect();
    if got_down != want_down {
        return Err("down_osds() diverged from the Vec<bool> scan".into());
    }
    for o in 0..model.len() {
        let osd = o as OsdId;
        if state.osd_is_up(osd) != model[o] {
            return Err(format!("osd_is_up({osd}) != model"));
        }
        // the utilization-index mirror: up AND nonzero capacity
        let want_indexed = model[o] && state.osd_size(osd) > 0;
        if state.osd_is_indexed(osd) != want_indexed {
            return Err(format!(
                "osd_is_indexed({osd}) = {} but model says {want_indexed}",
                state.osd_is_indexed(osd)
            ));
        }
    }
    Ok(())
}

/// Random up/down churn (no topology change): every accessor must track
/// the boolean model step for step.
#[test]
fn membership_matches_vec_bool_model_under_churn() {
    check_seeded("bitset-churn", 0xB175EC, 8, |rng| {
        let mut state = clusters::demo(rng.next_u64());
        let mut model = vec![true; state.osd_count()];
        assert_matches_model(&state, &model)?;
        for _ in 0..120 {
            let o = rng.below(model.len() as u64) as usize;
            let up = rng.chance(0.5);
            state.set_osd_up(o as OsdId, up);
            model[o] = up;
            assert_matches_model(&state, &model)?;
        }
        Ok(())
    });
}

/// Failures go through `fail_osd` (down + out + recovery backfills) —
/// the membership sets must agree with the model afterwards, including
/// through the aggregate rebuilds recovery triggers.
#[test]
fn membership_survives_fail_sequences() {
    check_seeded("bitset-fail", 0xFA11ED, 6, |rng| {
        let mut state = clusters::demo(rng.next_u64());
        let mut model = vec![true; state.osd_count()];
        // fail a few distinct devices, never the whole cluster
        for _ in 0..3 {
            let ups: Vec<OsdId> = state.up_osds().collect();
            if ups.len() <= state.osd_count() / 2 {
                break;
            }
            let victim = *rng.choose(&ups).expect("up devices remain");
            fail_osd(&mut state, victim);
            model[victim as usize] = false;
            assert_matches_model(&state, &model)?;
        }
        // interleave plain down/up marks with the failures
        for _ in 0..40 {
            let o = rng.below(model.len() as u64) as usize;
            let up = rng.chance(0.6);
            state.set_osd_up(o as OsdId, up);
            model[o] = up;
        }
        assert_matches_model(&state, &model)
    });
}

/// Host expansion grows the id universe; existing membership (including
/// down markers) must be preserved bit for bit and the new devices must
/// come up as members.
#[test]
fn membership_survives_universe_growth() {
    check_seeded("bitset-grow", 0x6B0EED, 6, |rng| {
        let mut state = clusters::demo(rng.next_u64());
        let mut model = vec![true; state.osd_count()];
        // pre-expansion churn so the preserved state is non-trivial
        for _ in 0..30 {
            let o = rng.below(model.len() as u64) as usize;
            let up = rng.chance(0.5);
            state.set_osd_up(o as OsdId, up);
            model[o] = up;
        }
        for round in 0..2 {
            let spec = HostSpec::hdd(1 + round, 2 + rng.below(3) as usize, 4 * TIB);
            let new = add_hosts(&mut state, &spec).map_err(|e| e.to_string())?;
            model.resize(model.len() + new.len(), true);
            assert_matches_model(&state, &model)?;
            // churn across the old/new boundary
            for _ in 0..20 {
                let o = rng.below(model.len() as u64) as usize;
                let up = rng.chance(0.5);
                state.set_osd_up(o as OsdId, up);
                model[o] = up;
            }
            assert_matches_model(&state, &model)?;
        }
        Ok(())
    });
}
