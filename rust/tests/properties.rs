//! Property-based tests over randomized clusters and workloads, using
//! the in-repo prop harness (seeded, reproducible).
//!
//! These pin the coordinator-level invariants: CRUSH legality of every
//! balancer decision, accounting integrity under arbitrary move/write
//! interleavings, executor concurrency limits, and scoring-backend
//! equivalence.

use equilibrium::balancer::scoring::{score_naive, MoveScorer, NativeScorer, ScoreRequest};
use equilibrium::balancer::{constraints, Balancer, Equilibrium, MgrBalancer};
use equilibrium::cluster::{dump, ClusterState};
use equilibrium::coordinator::{execute_plan, ExecutorConfig};
use equilibrium::crush::{CrushBuilder, DeviceClass, Level, NodeId, Rule};
use equilibrium::prop_assert;
use equilibrium::simulator::{simulate, SimOptions};
use equilibrium::util::prop::check_seeded;
use equilibrium::util::rng::Rng;
use equilibrium::util::units::{GIB, TIB};

use equilibrium::generator::synth::random_cluster;

#[test]
fn prop_equilibrium_moves_are_always_legal_and_variance_decreases() {
    check_seeded("equilibrium-legality", 0x51, 12, |rng| {
        let mut state = random_cluster(rng);
        let mut bal = Equilibrium::default();
        let mut moves = 0;
        while let Some(p) = bal.next_move(&state) {
            prop_assert!(
                constraints::check_move(&state, p.pg, p.from, p.to).is_ok(),
                "illegal proposal {p:?}"
            );
            let u_src = state.utilization(p.from);
            let u_dst = state.utilization(p.to);
            prop_assert!(u_dst < u_src, "dest {u_dst} not emptier than src {u_src}");
            state.apply_movement(p.pg, p.from, p.to).map_err(|e| e.to_string())?;
            moves += 1;
            prop_assert!(moves < 5000, "did not converge");
        }
        let problems = state.verify();
        prop_assert!(problems.is_empty(), "invariant drift: {problems:?}");
        Ok(())
    });
}

#[test]
fn prop_balancing_never_reduces_total_avail() {
    check_seeded("avail-monotone", 0x52, 10, |rng| {
        let mut state = random_cluster(rng);
        let before = state.total_max_avail(false);
        let mut bal = Equilibrium::default();
        simulate(&mut bal, &mut state, &SimOptions::default());
        let after = state.total_max_avail(false);
        prop_assert!(
            after >= before - 1.0,
            "balancing lost space: {before:.3e} -> {after:.3e}"
        );
        Ok(())
    });
}

#[test]
fn prop_mgr_moves_are_legal_and_converge_on_counts() {
    check_seeded("mgr-legality", 0x53, 10, |rng| {
        let mut state = random_cluster(rng);
        let mut bal = MgrBalancer::default();
        let mut moves = 0;
        while let Some(p) = bal.next_move(&state) {
            prop_assert!(
                constraints::check_move(&state, p.pg, p.from, p.to).is_ok(),
                "illegal mgr proposal {p:?}"
            );
            state.apply_movement(p.pg, p.from, p.to).map_err(|e| e.to_string())?;
            moves += 1;
            prop_assert!(moves < 10_000, "mgr did not converge");
        }
        prop_assert!(state.verify().is_empty());
        Ok(())
    });
}

#[test]
fn prop_dump_roundtrip_on_random_clusters() {
    check_seeded("dump-roundtrip", 0x54, 10, |rng| {
        let state = random_cluster(rng);
        let text = dump::dump(&state);
        let loaded = dump::load(&text).map_err(|e| e.to_string())?;
        prop_assert!(loaded.pg_count() == state.pg_count());
        for o in 0..state.osd_count() as u32 {
            prop_assert!(loaded.osd_used(o) == state.osd_used(o), "osd.{o} used drift");
        }
        prop_assert!(dump::dump(&loaded) == text, "second dump not byte-stable");
        Ok(())
    });
}

#[test]
fn prop_executor_respects_backfill_limits() {
    check_seeded("executor-limits", 0x55, 20, |rng| {
        let osds = 4 + rng.index(12);
        let n_moves = 1 + rng.index(40);
        let max_backfills = 1 + rng.index(3);
        let plan: Vec<equilibrium::cluster::Movement> = (0..n_moves)
            .map(|i| {
                let from = rng.index(osds) as u32;
                let mut to = rng.index(osds) as u32;
                if to == from {
                    to = (to + 1) % osds as u32;
                }
                equilibrium::cluster::Movement {
                    pg: equilibrium::cluster::PgId::new(1, i as u32),
                    from,
                    to,
                    bytes: 1 + rng.below(1 << 30),
                }
            })
            .collect();
        let cfg = ExecutorConfig { max_backfills, bandwidth: 100.0 * GIB as f64 };
        let report = execute_plan(&plan, &cfg, osds).unwrap();
        prop_assert!(report.transfers.len() == plan.len(), "all transfers must run");

        // instantaneous concurrency per OSD must never exceed the limit:
        // sweep start/finish events (finish before start at equal times —
        // a freed slot is reusable immediately)
        for osd in 0..osds as u32 {
            let mut events: Vec<(f64, i32)> = Vec::new();
            for t in &report.transfers {
                if t.movement.from == osd || t.movement.to == osd {
                    events.push((t.start, 1));
                    events.push((t.finish, -1));
                }
            }
            events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let mut running = 0i32;
            for (time, delta) in events {
                running += delta;
                prop_assert!(
                    running <= max_backfills as i32,
                    "osd.{osd} had {running} concurrent transfers at t={time} (limit {max_backfills})"
                );
            }
        }
        // makespan lower bound: total bytes / (bandwidth × max possible lanes)
        let serial: f64 = report.total_bytes as f64 / cfg.bandwidth;
        prop_assert!(report.makespan >= serial / (osds as f64 * max_backfills as f64) - 1e-9);
        Ok(())
    });
}

#[test]
fn prop_native_scorer_matches_naive_reference() {
    check_seeded("scorer-parity", 0x56, 40, |rng| {
        let n = 2 + rng.index(300);
        let size: Vec<f64> = (0..n).map(|_| rng.range_f64(1e11, 3e13)).collect();
        let used: Vec<f64> = size.iter().map(|&s| s * rng.range_f64(0.0, 0.99)).collect();
        let src = rng.index(n);
        let shard = used[src] * rng.range_f64(0.0, 1.0);
        let mask: Vec<bool> = (0..n).map(|_| rng.chance(0.6)).collect();
        let req = ScoreRequest { used: &used, size: &size, src, shard, mask: &mask };
        let a = NativeScorer.score(&req);
        let b = score_naive(&req);
        prop_assert!((a.var_before - b.var_before).abs() < 1e-10);
        for j in 0..n {
            let (x, y) = (a.var_after[j], b.var_after[j]);
            if x.is_finite() != y.is_finite() {
                return Err(format!("finiteness mismatch at {j}"));
            }
            if x.is_finite() {
                prop_assert!((x - y).abs() < 1e-10, "slot {j}: {x} vs {y}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_crush_mappings_respect_failure_domains_on_random_trees() {
    check_seeded("crush-domains", 0x57, 15, |rng| {
        let racks = 2 + rng.index(3);
        let hosts_per_rack = 2 + rng.index(3);
        let osds_per_host = 1 + rng.index(3);
        let mut b = CrushBuilder::new();
        let root = b.add_root("default");
        for r in 0..racks {
            let rack = b.add_bucket(&format!("rack{r}"), Level::Rack, root);
            for h in 0..hosts_per_rack {
                let host = b.add_bucket(&format!("host{r}x{h}"), Level::Host, rack);
                for _ in 0..osds_per_host {
                    b.add_osd_bytes(host, (1 + rng.below(8)) * TIB, DeviceClass::Hdd);
                }
            }
        }
        let domain = if rng.chance(0.5) { Level::Host } else { Level::Rack };
        b.add_rule(Rule::replicated(0, "r", "default", None, domain));
        let map = b.build().map_err(|e| e.to_string())?;
        let rule = map.rule(0).unwrap();
        let n_domains = if domain == Level::Host { racks * hosts_per_rack } else { racks };
        let replicas = 2 + rng.index(2); // 2 or 3
        for pg in 0..200u32 {
            let slots =
                equilibrium::crush::map_rule(&map, rule, equilibrium::crush::pg_input(1, pg), replicas);
            let devs: Vec<u32> = slots.iter().filter_map(|s| *s).collect();
            if replicas <= n_domains {
                prop_assert!(devs.len() == replicas, "pg {pg}: wanted {replicas}, got {devs:?}");
            }
            let mut domains: Vec<NodeId> = devs
                .iter()
                .map(|&d| map.ancestor_at(d as NodeId, domain).unwrap())
                .collect();
            domains.sort_unstable();
            domains.dedup();
            prop_assert!(domains.len() == devs.len(), "pg {pg}: domain collision");
        }
        Ok(())
    });
}

#[test]
fn prop_write_then_balance_interleaving_keeps_accounting() {
    check_seeded("interleave-accounting", 0x58, 8, |rng| {
        let mut state = random_cluster(rng);
        let mut bal = Equilibrium::default();
        for _ in 0..20 {
            // random writes
            let pgs: Vec<_> = state.pgs().map(|p| p.id()).collect();
            for _ in 0..5 {
                let pg = *rng.choose(&pgs).unwrap();
                let _ = state.grow_pg(pg, rng.below(2 * GIB));
            }
            // a few balancing steps
            for _ in 0..3 {
                let Some(p) = bal.next_move(&state) else { break };
                state.apply_movement(p.pg, p.from, p.to).map_err(|e| e.to_string())?;
            }
        }
        let problems = state.verify();
        prop_assert!(problems.is_empty(), "{problems:?}");
        Ok(())
    });
}

#[test]
fn prop_failure_recovery_keeps_invariants() {
    check_seeded("failure-recovery", 0x59, 8, |rng| {
        let mut state = random_cluster(rng);
        // fail 1-2 random OSDs, then balance
        for _ in 0..1 + rng.index(2) {
            let Some(victim) = equilibrium::cluster::random_up_osd(&state, rng) else {
                break;
            };
            // keep at least 4 up OSDs so recovery has room
            let ups = (0..state.osd_count() as u32)
                .filter(|&o| state.osd_is_up(o))
                .count();
            if ups <= 4 {
                break;
            }
            let report = equilibrium::cluster::fail_osd(&mut state, victim);
            // only explicitly-degraded PGs may still reference the victim
            // (no legal replacement existed, e.g. EC slots == live hosts)
            for pg in state.pgs() {
                if pg.on(victim) {
                    prop_assert!(
                        report.degraded.contains(&pg.id()),
                        "pg {} on failed osd but not reported degraded",
                        pg.id()
                    );
                }
            }
        }
        let mut bal = Equilibrium::default();
        let mut moves = 0;
        while let Some(p) = bal.next_move(&state) {
            prop_assert!(state.osd_is_up(p.to), "balancer must not target down OSDs");
            state.apply_movement(p.pg, p.from, p.to).map_err(|e| e.to_string())?;
            moves += 1;
            prop_assert!(moves < 5000, "did not converge after failures");
        }
        prop_assert!(state.verify().is_empty());
        Ok(())
    });
}

/// `Workload::write` contract, for all three models: applied bytes never
/// exceed the request, the raw growth is conserved across pools (the sum
/// of per-pool raw growth equals the cluster-wide growth, bounded by the
/// request times the worst redundancy overhead), and identical seeds
/// replay identical write streams.
#[test]
fn prop_workload_write_bounds_conservation_and_determinism() {
    use equilibrium::cluster::PoolKind;
    use equilibrium::simulator::{Workload, WorkloadModel};
    use std::collections::BTreeMap;

    fn pool_raw(state: &ClusterState) -> BTreeMap<u32, u64> {
        let mut out = BTreeMap::new();
        for pg in state.pgs() {
            *out.entry(pg.id().pool).or_insert(0) +=
                pg.shard_bytes() * pg.devices().count() as u64;
        }
        out
    }

    check_seeded("workload-models", 0x5A, 16, |rng| {
        let state = random_cluster(rng);
        let user_pool = state
            .pools
            .values()
            .find(|p| p.kind == PoolKind::UserData)
            .map(|p| p.id)
            .unwrap_or(1);
        let models = [
            WorkloadModel::Uniform,
            WorkloadModel::ZipfPools { exponent: rng.range_f64(0.5, 1.5) },
            WorkloadModel::Hotspot { pool: user_pool, fraction: 0.9 },
        ];
        for model in models {
            let request = (1 + rng.below(64)) * GIB;
            let wseed = rng.next_u64();
            let mut s1 = state.clone();
            let mut s2 = state.clone();
            let written1 = Workload::new(model.clone(), wseed).write(&mut s1, request);
            let written2 = Workload::new(model.clone(), wseed).write(&mut s2, request);

            // 1. returned bytes never exceed the request
            prop_assert!(
                written1 <= request,
                "{model:?}: wrote {written1} > requested {request}"
            );

            // 2. identical seeds produce identical streams
            prop_assert!(written1 == written2, "{model:?}: same seed diverged");
            prop_assert!(
                s1.total_used() == s2.total_used(),
                "{model:?}: same seed, different cluster"
            );

            // 3. conservation: per-pool raw growth sums to the total raw
            //    growth, and stays under request × worst redundancy
            //    overhead (plus per-shard rounding slack)
            let before = pool_raw(&state);
            let after = pool_raw(&s1);
            let per_pool_growth: u64 = after
                .iter()
                .map(|(id, &raw)| raw - before.get(id).copied().unwrap_or(0))
                .sum();
            let total_growth = s1.total_used() - state.total_used();
            prop_assert!(
                per_pool_growth == total_growth,
                "{model:?}: pool growth {per_pool_growth} != cluster growth {total_growth}"
            );
            let worst_ratio = state
                .pools
                .values()
                .map(|p| p.redundancy.raw_ratio())
                .fold(0.0f64, f64::max);
            let slack = 64.0 * 16.0; // ≤0.5 B rounding per shard per hit
            prop_assert!(
                total_growth as f64 <= request as f64 * worst_ratio + slack,
                "{model:?}: raw growth {total_growth} exceeds {request} × {worst_ratio}"
            );
            prop_assert!(s1.verify().is_empty(), "{model:?}: {:?}", s1.verify());
        }
        Ok(())
    });
}

/// Zipf ranks are assigned by ascending pool id (the satellite fix):
/// with a strong exponent, the lowest-id user pool must take the largest
/// share of the writes.
#[test]
fn prop_zipf_ranks_follow_pool_ids() {
    use equilibrium::cluster::Pool;
    use equilibrium::simulator::{Workload, WorkloadModel};

    let mut b = CrushBuilder::new();
    let root = b.add_root("default");
    for h in 0..4 {
        let host = b.add_bucket(&format!("host{h}"), Level::Host, root);
        b.add_osd_bytes(host, 8 * TIB, DeviceClass::Hdd);
    }
    b.add_rule(Rule::replicated(0, "r", "default", None, Level::Host));
    let pools = vec![
        Pool::replicated(1, "p1", 3, 32, 0),
        Pool::replicated(2, "p2", 3, 32, 0),
        Pool::replicated(3, "p3", 3, 32, 0),
    ];
    let state = ClusterState::build(b.build().unwrap(), pools, |_, _| GIB);

    let pool_raw = |s: &ClusterState, pool: u32| -> u64 {
        s.pgs_of_pool(pool)
            .map(|p| p.shard_bytes() * p.devices().count() as u64)
            .sum()
    };
    let mut s = state.clone();
    let mut w = Workload::new(WorkloadModel::ZipfPools { exponent: 2.0 }, 11);
    w.write(&mut s, 300 * GIB);
    let g1 = pool_raw(&s, 1) - pool_raw(&state, 1);
    let g2 = pool_raw(&s, 2) - pool_raw(&state, 2);
    let g3 = pool_raw(&s, 3) - pool_raw(&state, 3);
    assert!(g1 > g2 && g2 > g3, "zipf shares must fall with pool id: {g1} {g2} {g3}");
}
