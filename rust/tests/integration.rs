//! Cross-module integration tests: generator → dump/load → balancers →
//! simulator → coordinator, plus the CLI binary and the XLA runtime
//! when artifacts are present.

use equilibrium::balancer::{constraints, Equilibrium, EquilibriumConfig, MgrBalancer};
use equilibrium::cluster::dump;
use equilibrium::coordinator::{execute_plan, run_daemon, DaemonConfig, ExecutorConfig};
use equilibrium::crush::{Level, NodeId};
use equilibrium::generator::clusters;
use equilibrium::runtime::{Runtime, XlaScorer};
use equilibrium::simulator::{compare, simulate, SimOptions};
use std::process::Command;

/// The full pipeline on paper cluster C: balance, verify invariants,
/// execute the plan.
#[test]
fn full_pipeline_on_cluster_c() {
    let cluster = clusters::by_name("c", 0).unwrap();
    let initial = cluster.state;

    let (mgr, eq) = compare(
        &initial,
        || Box::new(MgrBalancer::default()),
        || Box::new(Equilibrium::default()),
        &SimOptions::default(),
    );

    // headline claims on C (Table 1: ours gains more on the data pools)
    let user: Vec<u32> = initial
        .pools
        .values()
        .filter(|p| p.kind == equilibrium::cluster::PoolKind::UserData)
        .map(|p| p.id)
        .collect();
    assert!(eq.series.total_gained(Some(&user)) >= mgr.series.total_gained(Some(&user)));
    assert!(eq.converged);

    // replay equilibrium's movements onto a fresh state and verify
    // everything: accounting, CRUSH legality of the *final* placement
    let mut state = clusters::by_name("c", 0).unwrap().state;
    for m in &eq.movements {
        assert!(
            constraints::check_move(&state, m.pg, m.from, m.to).is_ok(),
            "movement {m} violates constraints at apply time"
        );
        state.apply_movement(m.pg, m.from, m.to).unwrap();
    }
    assert!(state.verify().is_empty());

    // every PG of every pool still satisfies its failure domain
    for pg in state.pgs() {
        let pool = &state.pools[&pg.id().pool];
        let rule = state.crush.rule(pool.rule_id).unwrap();
        let cs = constraints::rule_slot_constraints(&state, rule, pool.redundancy.shard_count());
        for block in &cs {
            for level in &block.distinct_at {
                if *level == Level::Osd {
                    continue;
                }
                let mut domains = Vec::new();
                for s in block.slots.clone() {
                    if let Some(osd) = pg.acting_osd(s) {
                        if let Some(d) = state.crush.ancestor_at(osd as NodeId, *level) {
                            assert!(
                                !domains.contains(&d),
                                "pg {} violates {level:?} distinctness after balancing",
                                pg.id()
                            );
                            domains.push(d);
                        }
                    }
                }
            }
        }
    }

    // execute the plan through the coordinator
    let report = execute_plan(&eq.movements, &ExecutorConfig::default(), state.osd_count()).unwrap();
    assert_eq!(report.transfers.len(), eq.movements.len());
    assert!(report.makespan > 0.0);
}

/// Balancing a dumped-and-reloaded state gives identical results to
/// balancing the original (the dump is lossless for the balancer).
#[test]
fn dump_load_is_transparent_to_balancing() {
    let original = clusters::demo(5);
    let reloaded = dump::load(&dump::dump(&original)).unwrap();

    let mut s1 = original.clone();
    let mut s2 = reloaded;
    let mut b1 = Equilibrium::default();
    let mut b2 = Equilibrium::default();
    let r1 = simulate(&mut b1, &mut s1, &SimOptions::default());
    let r2 = simulate(&mut b2, &mut s2, &SimOptions::default());

    assert_eq!(r1.movements.len(), r2.movements.len());
    for (a, b) in r1.movements.iter().zip(&r2.movements) {
        assert_eq!((a.pg, a.from, a.to, a.bytes), (b.pg, b.from, b.to, b.bytes));
    }
}

/// XLA and native scoring backends drive the balancer to equivalent
/// results (same state quality; the exact move sequence may differ only
/// by float noise, so we compare outcomes).
#[test]
fn xla_and_native_backends_agree_end_to_end() {
    if !Runtime::artifacts_present(&equilibrium::runtime::default_artifact_dir()) {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let initial = clusters::demo(11);

    let mut native_state = initial.clone();
    let mut native_bal = Equilibrium::default();
    let native = simulate(&mut native_bal, &mut native_state, &SimOptions::default());

    let mut xla_state = initial.clone();
    let mut xla_bal =
        Equilibrium::new(EquilibriumConfig::default(), XlaScorer::load_default().unwrap());
    let xla = simulate(&mut xla_bal, &mut xla_state, &SimOptions::default());

    // identical decision sequences expected (same tie-breaking, same
    // f64 math) — but allow outcome-equivalence as the contract
    let v_native = native_state.utilization_variance();
    let v_xla = xla_state.utilization_variance();
    assert!(
        (v_native - v_xla).abs() < 1e-9,
        "final variance differs: native {v_native}, xla {v_xla}"
    );
    assert_eq!(native.movements.len(), xla.movements.len());
}

/// Daemon loop keeps cluster invariants under concurrent writes.
#[test]
fn daemon_preserves_invariants_under_write_load() {
    let mut state = clusters::demo(3);
    let mut bal = Equilibrium::default();
    let cfg = DaemonConfig {
        rounds: 6,
        moves_per_round: 10,
        write_bytes_per_round: 16 << 30,
        ..Default::default()
    };
    let report = run_daemon(&mut state, &mut bal, &cfg);
    assert_eq!(report.rounds.len(), 6);
    assert!(state.verify().is_empty());
    // variance stays bounded even under writes
    let last = report.rounds.last().unwrap();
    assert!(last.variance_after < 0.05);
}

/// Production lifecycle: balance → age (pools grow/shrink unevenly) →
/// the daemon restores balance under backfill throttling.
#[test]
fn aged_cluster_lifecycle() {
    use equilibrium::generator::{age, AgingConfig};

    let mut state = clusters::demo(61);
    // initial balance
    let mut bal = Equilibrium::default();
    equilibrium::balancer::run_to_convergence(&mut bal, &mut state, 10_000);
    let balanced_var = state.utilization_variance();

    // months of uneven growth
    age(&mut state, &AgingConfig::default(), 17);
    let drifted_var = state.utilization_variance();
    assert!(drifted_var > balanced_var);

    // operational recovery with adaptive throttle
    let mut bal2 = Equilibrium::default();
    let cfg = DaemonConfig {
        rounds: 20,
        moves_per_round: 10,
        write_bytes_per_round: 0,
        target_round_seconds: Some(600.0),
        ..Default::default()
    };
    let report = run_daemon(&mut state, &mut bal2, &cfg);
    assert!(report.rounds.iter().any(|r| r.converged), "daemon must converge again");
    assert!(
        state.utilization_variance() < drifted_var,
        "recovery must reduce drift: {} -> {}",
        drifted_var,
        state.utilization_variance()
    );
    assert!(state.verify().is_empty());
}

/// CLI smoke tests (binary built by cargo for integration tests).
#[test]
fn cli_generate_balance_roundtrip() {
    let bin = env!("CARGO_BIN_EXE_equilibrium");
    let dir = std::env::temp_dir().join(format!("eq_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let state_path = dir.join("demo.json");

    let out = Command::new(bin)
        .args(["generate", "--cluster", "demo", "--seed", "3"])
        .args(["--out", state_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "generate failed: {}", String::from_utf8_lossy(&out.stderr));

    let out = Command::new(bin)
        .args(["balance", "--state", state_path.to_str().unwrap(), "--quiet"])
        .output()
        .unwrap();
    assert!(out.status.success(), "balance failed: {}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("moves"), "summary missing: {stderr}");

    // plan pipeline end to end: optimized + phased plan, per-phase script
    let script_path = dir.join("phased.sh");
    let out = Command::new(bin)
        .args(["balance", "--state", state_path.to_str().unwrap(), "--quiet"])
        .args(["--optimize", "--phases"])
        .args(["--upmap-script", script_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "piped balance failed: {}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("optimized:"), "optimizer summary missing: {stderr}");
    assert!(stderr.contains("scheduled:"), "scheduler summary missing: {stderr}");
    let script = std::fs::read_to_string(&script_path).unwrap();
    assert!(script.contains("# phase 1/"), "phase headers missing");
    equilibrium::balancer::upmap_script::parse_script(&script).expect("script must parse back");

    let out = Command::new(bin).args(["simulate", "--cluster", "demo"]).output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("equilibrium"));
    assert!(stdout.contains("mgr"));

    // unknown args fail cleanly
    let out = Command::new(bin).args(["balance", "--nope"]).output().unwrap();
    assert!(!out.status.success());

    let _ = std::fs::remove_dir_all(&dir);
}

/// `report ablate-k` exercises the ablation path end to end.
#[test]
fn cli_report_ablate_runs() {
    let bin = env!("CARGO_BIN_EXE_equilibrium");
    let out = Command::new(bin)
        .args(["report", "ablate-count", "--cluster", "a"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("on (paper)"));
}
